//! Online-component integration on the REAL threaded pipeline:
//! Table II behaviour (exit ratio monotone in correlation, transmission
//! savings), adaptive precision under bandwidth drops, and accuracy
//! audits of early exits. Skips without artifacts. These runs execute
//! the actual PJRT artifacts with real wall-clock pacing, so task
//! counts are kept small.

use coach::coordinator::server::{serve, SchemePolicy, ServeCfg, ServeReplan};
use coach::network::{BandwidthModel, Trace};
use coach::runtime::{default_artifact_dir, Engine, Manifest};
use coach::sim::Correlation;

/// Artifacts AND a working engine (the PJRT backend is feature-gated;
/// the default build's stub Engine errors, so these tests skip).
fn load() -> Option<Manifest> {
    let m = Manifest::load(&default_artifact_dir()).ok()?;
    Engine::new(&m).ok()?;
    Some(m)
}

fn base_cfg(model: &str, m: &Manifest) -> ServeCfg {
    let blocks = m.models[model].blocks.len();
    ServeCfg {
        model: model.to_string(),
        cut: (blocks - 1) / 2,
        policy: SchemePolicy::coach(),
        device_scale: 4.0,
        bw: BandwidthModel::Static(20.0),
        period: 0.008,
        n_tasks: 90,
        correlation: Correlation::High,
        eps: 0.005,
        seed: 17,
        audit_every: 3,
        n_streams: 1,
        drop_after: None,
        queue_cap: 8,
        runtime: coach::serve::Runtime::Threaded,
        replan: None,
        cloud: coach::pipeline::BatchCfg::default(),
    }
}

#[test]
fn exit_ratio_monotone_in_correlation_real_pipeline() {
    let Some(m) = load() else { return };
    let mut ratios = Vec::new();
    for corr in [Correlation::Low, Correlation::High] {
        let cfg = ServeCfg { correlation: corr, ..base_cfg("resnet_mini", &m) };
        let res = serve(&m, &cfg).unwrap();
        ratios.push(res.report.exit_ratio());
    }
    assert!(
        ratios[1] > ratios[0] + 0.05,
        "high-corr exits {:.2} not above low-corr {:.2}",
        ratios[1],
        ratios[0]
    );
}

#[test]
fn coach_transmits_less_than_noadjust() {
    let Some(m) = load() else { return };
    let coach = serve(&m, &base_cfg("vgg_mini", &m)).unwrap();
    let cfg = ServeCfg {
        policy: SchemePolicy::no_adjust(),
        ..base_cfg("vgg_mini", &m)
    };
    let noadj = serve(&m, &cfg).unwrap();
    assert!(
        coach.report.avg_wire_kb() < noadj.report.avg_wire_kb() * 0.8,
        "COACH wire {:.1} Kb vs NoAdjust {:.1} Kb",
        coach.report.avg_wire_kb(),
        noadj.report.avg_wire_kb()
    );
    assert_eq!(noadj.report.exit_ratio(), 0.0);
}

#[test]
fn early_exits_pass_accuracy_audit() {
    let Some(m) = load() else { return };
    let mut cfg = base_cfg("resnet_mini", &m);
    cfg.audit_every = 1; // audit every exit
    cfg.n_tasks = 80;
    let res = serve(&m, &cfg).unwrap();
    if res.report.exit_ratio() > 0.1 {
        // audited accuracy over exited tasks must stay near the eps
        // budget the thresholds were calibrated for
        let exited: Vec<_> =
            res.report.tasks.iter().filter(|t| t.exited_early).collect();
        let correct =
            exited.iter().filter(|t| t.correct).count() as f64;
        let acc = correct / exited.len() as f64;
        assert!(acc >= 0.9, "audited early-exit accuracy {acc:.3}");
    }
}

#[test]
fn bandwidth_drop_lowers_transmitted_bits() {
    let Some(m) = load() else { return };
    let mut cfg = base_cfg("vgg_mini", &m);
    cfg.policy = SchemePolicy { early_exit: false, ..SchemePolicy::coach() };
    cfg.n_tasks = 120;
    let span = cfg.n_tasks as f64 * cfg.period;
    cfg.bw = BandwidthModel::Stepped(Trace {
        steps: vec![(0.0, 50.0), (span / 2.0, 2.0)],
    });
    let res = serve(&m, &cfg).unwrap();
    let transmitted: Vec<_> =
        res.report.tasks.iter().filter(|t| !t.exited_early).collect();
    let n = transmitted.len();
    assert!(n > 40, "need transmissions, got {n}");
    let first: f64 = transmitted[..n / 3]
        .iter()
        .map(|t| t.bits as f64)
        .sum::<f64>()
        / (n / 3) as f64;
    let last: f64 = transmitted[2 * n / 3..]
        .iter()
        .map(|t| t.bits as f64)
        .sum::<f64>()
        / (n - 2 * n / 3) as f64;
    assert!(
        last <= first + 0.25,
        "bits did not adapt down: first {first:.2} last {last:.2}"
    );
}

#[test]
fn serve_rejects_out_of_range_cut() {
    let Some(m) = load() else { return };
    let mut cfg = base_cfg("vgg_mini", &m);
    cfg.cut = 99;
    assert!(serve(&m, &cfg).is_err());
}

#[test]
fn server_swaps_cut_live_when_the_network_collapses() {
    let Some(m) = load() else { return };
    let mut cfg = base_cfg("resnet_mini", &m);
    let blocks = m.models["resnet_mini"].blocks.len();
    // ladder: collapse -> the deepest valid cut (small wire), healthy
    // network -> the configured mid cut
    let deep = blocks - 2;
    cfg.replan = Some(ServeReplan {
        ladder: vec![(0.5, deep), (10.0, cfg.cut)],
        k: 3,
    });
    cfg.n_tasks = 90;
    let span = cfg.n_tasks as f64 * cfg.period;
    cfg.bw = BandwidthModel::Stepped(Trace {
        steps: vec![(0.0, 50.0), (span / 3.0, 1.0)],
    });
    let res = serve(&m, &cfg).unwrap();
    let r = &res.per_stream[0];
    assert!(
        r.plan.switches >= 1,
        "bandwidth collapse must switch the cut live"
    );
    assert!(
        r.plan.occupancy.iter().filter(|&&c| c > 0).count() >= 2,
        "tasks must have run on both rungs: {:?}",
        r.plan.occupancy
    );
    assert_eq!(r.tasks.len() + r.dropped, cfg.n_tasks);
}

#[test]
fn serve_rejects_a_non_ascending_replan_ladder() {
    let Some(m) = load() else { return };
    let mut cfg = base_cfg("resnet_mini", &m);
    cfg.replan =
        Some(ServeReplan { ladder: vec![(10.0, 1), (2.0, 2)], k: 3 });
    assert!(serve(&m, &cfg).is_err());
}
