//! Cloud-scheduler correctness gates for `pipeline::batch`.
//!
//! * **b=1 parity** — `DynBatch` with `max_batch = 1` must reproduce
//!   the legacy FIFO cloud timeline BIT-FOR-BIT on both event-queue
//!   engines: a single-item batch goes through `service_secs(x, 1)`,
//!   which is the exact identity (`ALPHA + (1-ALPHA) = 1.0` in IEEE
//!   754), so any divergence means the batcher reordered admissions
//!   or touched the arithmetic.
//! * **conservation** — under batching with a mixed drop/exit fleet,
//!   every admitted task is reported exactly once per stream (no task
//!   lost inside a coalesced launch, none double-finished) and every
//!   stream reports.

use coach::model::topology::vgg16;
use coach::model::{CostModel, DeviceProfile, ModelGraph};
use coach::network::BandwidthModel;
use coach::pipeline::{
    run_virtual_streams, ActivePlan, BatchCfg, CloudPolicy, QueueEngine,
    StageModel, StaticPolicy, VirtualCfg, VirtualStream,
};
use coach::sim::{generate, Correlation, SimTask};

const N_STREAMS: usize = 8;
const TASKS: usize = 25;

fn stage_model() -> StageModel {
    StageModel {
        t_e: 1e-3,
        t_c: 5e-3,
        first_send_offset: 0.0,
        t_c_par: 0.0,
        cut_elems: vec![512],
        result_elems: 10,
        exit_check: 0.0,
    }
}

fn fleet_tasks(corr: Correlation) -> Vec<Vec<SimTask>> {
    (0..N_STREAMS)
        .map(|i| {
            let mut tasks = generate(TASKS, 4e-3, corr, 10, i as u64);
            let offset = 4e-3 * i as f64 / N_STREAMS as f64;
            for t in tasks.iter_mut() {
                t.arrive += offset;
            }
            tasks
        })
        .collect()
}

/// Run one fleet and return the per-stream (task bit patterns,
/// dropped count) — arrive/finish/latency compared as raw u64 bits so
/// formatting can't mask an ULP of drift.
fn run_fleet(
    tls: &[Vec<SimTask>],
    g: &ModelGraph,
    cost: &CostModel,
    engine: QueueEngine,
    cloud: BatchCfg,
    exit_threshold: f64,
    drop_after: Option<f64>,
    mbps: f64,
) -> Vec<(Vec<(usize, u64, u64, u64, bool)>, usize)> {
    let sm = stage_model();
    let bw = BandwidthModel::Static(mbps);
    let mut pols: Vec<StaticPolicy> = (0..N_STREAMS)
        .map(|_| StaticPolicy { bits: 8, exit_threshold })
        .collect();
    let mut plans: Vec<ActivePlan> =
        (0..N_STREAMS).map(|_| ActivePlan::single(sm.clone())).collect();
    let mut streams: Vec<VirtualStream<'_>> = tls
        .iter()
        .zip(pols.iter_mut())
        .zip(plans.iter_mut())
        .enumerate()
        .map(|(i, ((tasks, pol), plan))| VirtualStream {
            tasks,
            plan,
            graph: g,
            cost,
            policy: pol,
            scheme: "cloud-batch".into(),
            // mixed admission: half the fleet sheds aggressively
            drop_after: if i % 2 == 0 { drop_after } else { None },
        })
        .collect();
    let cfg = VirtualCfg {
        queue_cap: Some(4),
        engine,
        cloud,
        ..VirtualCfg::default()
    };
    let multi = run_virtual_streams(&mut streams, &bw, cfg);
    assert_eq!(multi.per_stream.len(), N_STREAMS, "every stream reports");
    multi
        .per_stream
        .iter()
        .map(|r| {
            (
                r.tasks
                    .iter()
                    .map(|t| {
                        (
                            t.id,
                            t.arrive.to_bits(),
                            t.finish.to_bits(),
                            t.latency.to_bits(),
                            t.exited_early,
                        )
                    })
                    .collect(),
                r.dropped,
            )
        })
        .collect()
}

/// `DynBatch` with `max_batch = 1` is the FIFO timeline, bit-for-bit,
/// on the heap AND calendar engines (which are themselves pinned
/// bit-identical elsewhere — so all four runs must agree).
#[test]
fn dynbatch_b1_matches_fifo_bit_for_bit_on_both_engines() {
    let g = vgg16();
    let cost =
        CostModel::new(DeviceProfile::jetson_nx(), DeviceProfile::cloud_a6000());
    let tls = fleet_tasks(Correlation::Low);
    let fifo = BatchCfg::default();
    let b1 = BatchCfg {
        policy: CloudPolicy::DynBatch,
        max_batch: 1,
        ..BatchCfg::default()
    };
    let golden = run_fleet(
        &tls,
        &g,
        &cost,
        QueueEngine::Heap,
        fifo,
        f64::INFINITY,
        None,
        200.0,
    );
    for engine in [QueueEngine::Heap, QueueEngine::Calendar] {
        for cloud in [fifo, b1] {
            let got = run_fleet(
                &tls,
                &g,
                &cost,
                engine,
                cloud,
                f64::INFINITY,
                None,
                200.0,
            );
            assert_eq!(
                got, golden,
                "{engine:?}/{:?} diverged from heap/fifo",
                cloud.policy
            );
        }
    }
}

/// Conservation under real batching: a mixed fleet (early exits from
/// high correlation, drops on half the streams) where the batcher
/// actually coalesces. Every admitted task id must appear exactly
/// once in its stream's report, and admitted + dropped must account
/// for the full workload.
#[test]
fn batched_fleet_reports_every_admitted_task_exactly_once() {
    let g = vgg16();
    let cost =
        CostModel::new(DeviceProfile::jetson_nx(), DeviceProfile::cloud_a6000());
    let tls = fleet_tasks(Correlation::High);
    for policy in [CloudPolicy::DynBatch, CloudPolicy::SloAware] {
        let cloud = BatchCfg {
            policy,
            max_batch: 4,
            max_wait: 500e-6,
            slo: 0.05,
            ..BatchCfg::default()
        };
        // 2 Mbps: ~2 ms per wire crossing, so the shared link backs
        // up (drops engage on the shedding half of the fleet) AND the
        // 5 ms cloud stage still queues behind it (batches form)
        let per_stream = run_fleet(
            &tls,
            &g,
            &cost,
            QueueEngine::Calendar,
            cloud,
            0.6, // finite threshold: high-corr tasks exit early
            Some(2e-3),
            2.0,
        );
        let mut exited = 0usize;
        let mut dropped_total = 0usize;
        for (si, (tasks, dropped)) in per_stream.iter().enumerate() {
            assert_eq!(
                tasks.len() + dropped,
                TASKS,
                "stream {si}: admitted + dropped != workload ({policy:?})"
            );
            let mut ids: Vec<usize> = tasks.iter().map(|t| t.0).collect();
            ids.sort_unstable();
            ids.dedup();
            assert_eq!(
                ids.len(),
                tasks.len(),
                "stream {si}: duplicate task id in report ({policy:?})"
            );
            exited += tasks.iter().filter(|t| t.4).count();
            dropped_total += dropped;
        }
        // the fleet must actually exercise the mixed regime the test
        // claims to cover
        assert!(exited > 0, "no early exits — workload too easy ({policy:?})");
        assert!(
            dropped_total > 0,
            "no drops — admission never engaged ({policy:?})"
        );
    }
}
