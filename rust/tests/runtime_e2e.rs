//! Runtime-backed integration: PJRT engine vs the rust UAQ mirror,
//! blockwise-vs-split numerics, GAP vs a host-side reference.
//! Requires `make artifacts`; every test skips cleanly if the artifact
//! directory is missing (CI without the python toolchain).

use coach::quant::uaq;
use coach::runtime::{default_artifact_dir, Engine, Manifest, ModelRuntime, Tensor};
use coach::util::Rng;

fn load() -> Option<Manifest> {
    let m = Manifest::load(&default_artifact_dir()).ok()?;
    // the PJRT backend is feature-gated (`pjrt`); without it Engine::new
    // errors and these tests skip even when artifacts exist
    Engine::new(&m).ok()?;
    Some(m)
}

fn input_from_pattern(m: &Manifest, class: usize) -> Tensor {
    let patterns = m.read_f32(&m.patterns.file).unwrap();
    let isz: usize = m.input_shape.iter().product();
    Tensor::new(m.input_shape.clone(), patterns[class * isz..(class + 1) * isz].to_vec())
        .unwrap()
}

#[test]
fn split_inference_matches_full_forward() {
    let Some(m) = load() else { return };
    let engine = Engine::new(&m).unwrap();
    for model in ["vgg_mini", "resnet_mini"] {
        let rt = ModelRuntime::new(&engine, &m, model).unwrap();
        let x = input_from_pattern(&m, 5);
        let full = rt.run_blocks(0, rt.model.blocks.len(), &x).unwrap();
        for cut in 0..rt.model.n_cuts() {
            let act = rt.run_device(cut, &x).unwrap();
            let out = rt.run_cloud(cut, &act).unwrap();
            assert_eq!(out.shape, full.shape);
            for (a, b) in out.data.iter().zip(&full.data) {
                assert!(
                    (a - b).abs() < 1e-3,
                    "{model} cut {cut}: {a} vs {b}"
                );
            }
        }
    }
}

#[test]
fn uaq_artifact_matches_rust_mirror() {
    let Some(m) = load() else { return };
    let engine = Engine::new(&m).unwrap();
    let rt = ModelRuntime::new(&engine, &m, "resnet_mini").unwrap();
    let mut rng = Rng::new(77);
    // use a real cut activation size so an artifact exists
    let elems = rt.model.cut_elems(1);
    let data: Vec<f32> = (0..elems).map(|_| rng.normal() as f32).collect();
    let shape = rt.model.cut_shape(1).to_vec();
    let x = Tensor::new(shape, data.clone()).unwrap();
    for bits in [2u8, 4, 6, 8] {
        let via_artifact = rt.uaq_roundtrip(&x, bits).unwrap();
        let via_rust = uaq::roundtrip(&data, bits);
        for (a, b) in via_artifact.data.iter().zip(&via_rust) {
            assert!(
                (a - b).abs() < 1e-4,
                "bits {bits}: artifact {a} vs rust {b}"
            );
        }
    }
}

#[test]
fn gap_artifact_matches_host_mean() {
    let Some(m) = load() else { return };
    let engine = Engine::new(&m).unwrap();
    let rt = ModelRuntime::new(&engine, &m, "resnet_mini").unwrap();
    let x = input_from_pattern(&m, 2);
    let act = rt.run_device(2, &x).unwrap();
    let feat = rt.gap_feature(&act).unwrap();
    let (c, h, w) = (act.shape[0], act.shape[1], act.shape[2]);
    assert_eq!(feat.elems(), c);
    for ch in 0..c {
        let mean: f32 = act.data[ch * h * w..(ch + 1) * h * w]
            .iter()
            .sum::<f32>()
            / (h * w) as f32;
        assert!(
            (feat.data[ch] - mean).abs() < 1e-4,
            "channel {ch}: {} vs {}",
            feat.data[ch],
            mean
        );
    }
}

#[test]
fn quantized_split_preserves_labels_at_high_bits() {
    let Some(m) = load() else { return };
    let engine = Engine::new(&m).unwrap();
    for model in ["vgg_mini", "resnet_mini"] {
        let rt = ModelRuntime::new(&engine, &m, model).unwrap();
        let mut agree = 0;
        let n = 6;
        for class in 0..n {
            let x = input_from_pattern(&m, class);
            let full = rt.run_blocks(0, rt.model.blocks.len(), &x).unwrap();
            let cut = rt.model.n_cuts() / 2;
            let act = rt.run_device(cut, &x).unwrap();
            let q = rt.uaq_roundtrip(&act, 8).unwrap();
            let out = rt.run_cloud(cut, &q).unwrap();
            if out.argmax() == full.argmax() {
                agree += 1;
            }
        }
        assert!(agree >= n - 1, "{model}: only {agree}/{n} agree at 8 bits");
    }
}

#[test]
fn acc_table_loaded_and_monotoneish() {
    let Some(m) = load() else { return };
    for (model, cuts) in &m.acc.table {
        for (cut, curve) in cuts {
            let lo = curve[&2];
            let hi = curve[&8];
            assert!(
                hi >= lo - 0.05,
                "{model} cut {cut}: 8-bit fidelity {hi} below 2-bit {lo}"
            );
            assert!(hi > 0.9, "{model} cut {cut}: 8-bit fidelity {hi} too low");
        }
    }
}

#[test]
fn profile_blocks_returns_positive_times() {
    let Some(m) = load() else { return };
    let engine = Engine::new(&m).unwrap();
    let rt = ModelRuntime::new(&engine, &m, "vgg_mini").unwrap();
    let secs = rt.profile_blocks(2).unwrap();
    assert_eq!(secs.len(), rt.model.blocks.len());
    assert!(secs.iter().all(|&s| s > 0.0 && s < 1.0));
}
