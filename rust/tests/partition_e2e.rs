//! Offline-component integration on the measured mini models: the
//! partitioner consuming real per-block profiles and the real measured
//! accuracy tables. Skips without artifacts.

use coach::model::{topology, CostModel, DeviceProfile};
use coach::partition::{optimize, MeasuredAcc, PartitionConfig};
use coach::runtime::{default_artifact_dir, Engine, Manifest, ModelRuntime};

fn mini_cost(scale: f64) -> CostModel {
    CostModel::new(DeviceProfile::mini_device(scale), DeviceProfile::mini_cloud())
}

#[test]
fn measured_partition_uses_acc_table_bits() {
    let Ok(m) = Manifest::load(&default_artifact_dir()) else { return };
    // the PJRT backend is feature-gated; skip on stub-engine builds
    let Ok(engine) = Engine::new(&m) else { return };
    for model in ["vgg_mini", "resnet_mini"] {
        let rt = ModelRuntime::new(&engine, &m, model).unwrap();
        let secs = rt.profile_blocks(2).unwrap();
        let g = topology::from_manifest(rt.model, &secs);
        let acc = MeasuredAcc { table: &m.acc, model: model.to_string() };
        let cfg = PartitionConfig { bw_mbps: 20.0, ..Default::default() };
        let s = optimize(&g, &mini_cost(6.0), &acc, &cfg).unwrap();
        // any chosen cut's bits must satisfy the measured table at eps
        for c in &s.cuts {
            // cut index = device blocks before the cut (input excluded)
            let cut_idx = (0..c.from)
                .filter(|&i| s.on_device[i] && g.layers[i].flops > 0.0)
                .count();
            let min = m.acc.min_bits(model, cut_idx, cfg.eps);
            assert_eq!(
                Some(c.bits),
                min,
                "{model}: cut {cut_idx} bits {} vs table {min:?}",
                c.bits
            );
        }
    }
}

#[test]
fn slower_device_offloads_no_less() {
    let Ok(m) = Manifest::load(&default_artifact_dir()) else { return };
    // the PJRT backend is feature-gated; skip on stub-engine builds
    let Ok(engine) = Engine::new(&m) else { return };
    let rt = ModelRuntime::new(&engine, &m, "resnet_mini").unwrap();
    let secs = rt.profile_blocks(2).unwrap();
    let g = topology::from_manifest(rt.model, &secs);
    let acc = MeasuredAcc { table: &m.acc, model: "resnet_mini".into() };
    let cfg = PartitionConfig { bw_mbps: 20.0, ..Default::default() };
    let fast = optimize(&g, &mini_cost(3.0), &acc, &cfg).unwrap();
    let slow = optimize(&g, &mini_cost(12.0), &acc, &cfg).unwrap();
    assert!(
        slow.n_device_layers() <= fast.n_device_layers(),
        "slow device kept more layers: {} vs {}",
        slow.n_device_layers(),
        fast.n_device_layers()
    );
}

#[test]
fn bandwidth_sweep_strategies_feasible() {
    let Ok(m) = Manifest::load(&default_artifact_dir()) else { return };
    // the PJRT backend is feature-gated; skip on stub-engine builds
    let Ok(engine) = Engine::new(&m) else { return };
    let rt = ModelRuntime::new(&engine, &m, "vgg_mini").unwrap();
    let secs = rt.profile_blocks(2).unwrap();
    let g = topology::from_manifest(rt.model, &secs);
    let acc = MeasuredAcc { table: &m.acc, model: "vgg_mini".into() };
    for bw in [1.0, 5.0, 20.0, 100.0] {
        let cfg = PartitionConfig { bw_mbps: bw, ..Default::default() };
        let s = optimize(&g, &mini_cost(6.0), &acc, &cfg).unwrap();
        assert!(g.cut_edges(&s.on_device).is_ok(), "bw {bw}");
        assert!(s.eval.objective().is_finite(), "bw {bw}");
    }
}
