//! Multi-stream serving e2e: N concurrent device streams feeding ONE
//! shared cloud stage through the FIFO link, driven by the wall-clock
//! driver (pipeline::driver::run_real) — the scheduling surface of the
//! multi-stream server.
//!
//! The first test uses the driver's simulated-compute stages so it runs
//! on any machine (no artifacts, no PJRT); the second exercises the full
//! PJRT server (`coordinator::server::serve` with `n_streams = 4`,
//! one shared cloud engine) and skips cleanly when artifacts are absent.

use coach::coordinator::server::{serve, SchemePolicy, ServeCfg};
use coach::metrics::MultiReport;
use coach::model::{CostModel, DeviceProfile};
use coach::network::BandwidthModel;
use coach::pipeline::driver::{run_real, RealCfg, SimCloud, SimDevice};
use coach::pipeline::{ActivePlan, StageModel, StaticPolicy, WallClock};
use coach::runtime::{default_artifact_dir, Engine, Manifest};
use coach::sim::{generate, Correlation, SimTask};

const N_TASKS: usize = 40;
const PERIOD: f64 = 0.007;
const T_E: f64 = 0.006;
const T_C: f64 = 0.001;

fn run_sim_streams(n_streams: usize) -> MultiReport {
    let clock = WallClock::new();
    let streams: Vec<(Vec<SimTask>, _)> = (0..n_streams)
        .map(|i| {
            let tasks = generate(
                N_TASKS,
                PERIOD,
                Correlation::Medium,
                10,
                77 + i as u64,
            );
            let bw = BandwidthModel::Static(50.0);
            let cost = CostModel::new(
                DeviceProfile::jetson_nx(),
                DeviceProfile::cloud_a6000(),
            );
            let sm = StageModel {
                t_e: T_E,
                t_c: T_C,
                first_send_offset: 0.0,
                t_c_par: 0.0,
                cut_elems: vec![2048],
                result_elems: 10,
                exit_check: 0.0,
            };
            let factory = move || -> anyhow::Result<SimDevice<StaticPolicy>> {
                Ok(SimDevice {
                    policy: StaticPolicy::no_exit(8),
                    plan: ActivePlan::single(sm),
                    bw,
                    clock,
                    source_elems: 2048,
                    cost,
                })
            };
            (tasks, factory)
        })
        .collect();
    run_real::<SimDevice<StaticPolicy>, SimCloud, _, _>(
        streams,
        || Ok(SimCloud),
        BandwidthModel::Static(50.0),
        clock,
        RealCfg { model: "sim".into(), ..Default::default() },
    )
    .unwrap()
}

#[test]
fn four_streams_share_one_cloud_and_beat_single_stream_throughput() {
    let single = run_sim_streams(1);
    assert_eq!(single.per_stream.len(), 1);
    let single_tp = single.aggregate_throughput();

    let multi = run_sim_streams(4);
    assert_eq!(multi.per_stream.len(), 4, "per-stream reports");
    for r in &multi.per_stream {
        assert_eq!(r.tasks.len(), N_TASKS, "stream completed all tasks");
        assert!(r.throughput() > 0.0);
    }
    let agg = multi.aggregate();
    // all non-exited tasks of every stream crossed the one shared cloud
    assert!(
        agg.cloud.busy > 3.0 * N_TASKS as f64 * T_C * 0.8,
        "shared cloud busy {:.3}s too small for 4 streams",
        agg.cloud.busy
    );
    let agg_tp = multi.aggregate_throughput();
    assert!(
        agg_tp > single_tp * 2.0,
        "4-stream aggregate {agg_tp:.1} it/s must exceed single-stream \
         {single_tp:.1} it/s"
    );
}

#[test]
fn pjrt_server_serves_four_streams_on_one_cloud_engine() {
    let Ok(m) = Manifest::load(&default_artifact_dir()) else { return };
    // the PJRT backend is feature-gated; skip on stub-engine builds
    if Engine::new(&m).is_err() {
        return;
    }
    let cfg = |n_streams: usize| ServeCfg {
        model: "resnet_mini".to_string(),
        cut: 1,
        policy: SchemePolicy::coach(),
        device_scale: 4.0,
        bw: BandwidthModel::Static(20.0),
        period: 0.012,
        n_tasks: 40,
        correlation: Correlation::High,
        eps: 0.005,
        seed: 23,
        audit_every: 0,
        n_streams,
        drop_after: None,
        queue_cap: 8,
        runtime: coach::serve::Runtime::Threaded,
        replan: None,
        cloud: coach::pipeline::BatchCfg::default(),
    };
    let single = serve(&m, &cfg(1)).unwrap();
    assert_eq!(single.per_stream.len(), 1);
    let multi = serve(&m, &cfg(4)).unwrap();
    assert_eq!(multi.per_stream.len(), 4);
    for r in &multi.per_stream {
        assert_eq!(r.tasks.len(), 40);
    }
    assert!(
        multi.report.throughput() > single.report.throughput(),
        "4-stream aggregate {:.1} it/s must exceed single-stream {:.1} it/s",
        multi.report.throughput(),
        single.report.throughput()
    );
}
