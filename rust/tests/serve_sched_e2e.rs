//! Scheduler-equivalence suite for the pluggable serving runtime
//! (`coach::serve`): the thread-per-stream reference engine and the
//! pooled worker engine must be behaviourally interchangeable. Both
//! drive the same sim-backed fleets through `run_real`; every DISCRETE
//! outcome field (ids, exit decisions, precisions, wire bytes, labels,
//! correctness, drop counts) must match exactly. Wall-clock fields
//! (arrive/finish/latency, stage busy seconds) are jitter-bearing by
//! construction and are deliberately NOT compared.

use coach::metrics::MultiReport;
use coach::model::{CostModel, DeviceProfile};
use coach::network::BandwidthModel;
use coach::pipeline::driver::{run_real, RealCfg, SimCloud, SimDevice};
use coach::pipeline::stage::{CloudStage, DeviceStage, DeviceVerdict};
use coach::pipeline::{
    ActivePlan, BatchCfg, CloudPolicy, StageModel, StaticPolicy, WallClock,
};
use coach::serve::Runtime;
use coach::sim::{generate, Correlation, SimTask};

/// Inter-arrival period per stream (seconds).
const PERIOD: f64 = 1e-3;

/// Workload shape of one fleet run: everything that must be identical
/// between the engines under comparison.
struct Fleet {
    n_streams: usize,
    n_tasks: usize,
    /// early-exit threshold on separability (INFINITY = never exit)
    exit_threshold: f64,
    /// feature elements crossing the link per transmitted task
    cut_elems: usize,
    link_mbps: f64,
    queue_cap: usize,
    /// cloud-side scheduler under test (fifo = legacy timeline)
    cloud: BatchCfg,
}

impl Fleet {
    fn stage_model(&self) -> StageModel {
        StageModel {
            t_e: 5e-4,
            t_c: 1e-4,
            first_send_offset: 0.0,
            t_c_par: 0.0,
            cut_elems: vec![self.cut_elems],
            result_elems: 10,
            exit_check: 0.0,
        }
    }

    /// Same seeds, same arrivals, same stage model — the only variable
    /// across calls is the serving engine.
    fn run(&self, runtime: Runtime) -> MultiReport {
        self.run_cfg(runtime, true, &[])
    }

    /// Like [`Fleet::run`], with the pooled engine's steal knob exposed
    /// and an optional per-stream device-compute scale (`skew[i]`
    /// multiplies stream `i`'s `t_e`; missing entries mean 1.0). Skew
    /// moves only wall-clock timing — every DISCRETE outcome is
    /// task-determined, which is exactly what the parity tests check.
    fn run_cfg(
        &self,
        runtime: Runtime,
        steal: bool,
        skew: &[f64],
    ) -> MultiReport {
        let clock = WallClock::new();
        let bw = BandwidthModel::Static(self.link_mbps);
        let base = self.stage_model();
        let streams: Vec<(Vec<SimTask>, _)> = (0..self.n_streams)
            .map(|i| {
                let tasks = generate(
                    self.n_tasks,
                    PERIOD,
                    Correlation::Medium,
                    10,
                    77 + i as u64,
                );
                let mut sm = base.clone();
                sm.t_e *= skew.get(i).copied().unwrap_or(1.0);
                let bw = bw.clone();
                let threshold = self.exit_threshold;
                let elems = self.cut_elems;
                let factory =
                    move || -> anyhow::Result<SimDevice<StaticPolicy>> {
                        Ok(SimDevice {
                            policy: StaticPolicy {
                                bits: 8,
                                exit_threshold: threshold,
                            },
                            plan: ActivePlan::single(sm),
                            bw,
                            clock,
                            source_elems: elems,
                            cost: CostModel::new(
                                DeviceProfile::jetson_nx(),
                                DeviceProfile::cloud_a6000(),
                            ),
                        })
                    };
                (tasks, factory)
            })
            .collect();
        run_real::<SimDevice<StaticPolicy>, SimCloud, _, _>(
            streams,
            || Ok(SimCloud),
            bw.clone(),
            clock,
            RealCfg {
                runtime,
                steal,
                queue_cap: self.queue_cap,
                scheme: "equiv".into(),
                model: "sim".into(),
                cloud: self.cloud,
                ..Default::default()
            },
        )
        .expect("fleet must serve")
    }
}

/// The discrete (jitter-free) projection of one task outcome.
type Discrete = (usize, bool, u8, usize, usize, bool);

fn discrete(multi: &MultiReport) -> Vec<(Vec<Discrete>, usize)> {
    multi
        .per_stream
        .iter()
        .map(|r| {
            let mut tasks: Vec<Discrete> = r
                .tasks
                .iter()
                .map(|t| {
                    (
                        t.id,
                        t.exited_early,
                        t.bits,
                        t.wire_bytes,
                        t.label,
                        t.correct,
                    )
                })
                .collect();
            tasks.sort_unstable();
            (tasks, r.dropped)
        })
        .collect()
}

/// Every discrete per-stream outcome must be identical across engines.
fn assert_equivalent(fleet: &Fleet) -> (MultiReport, MultiReport) {
    let threaded = fleet.run(Runtime::Threaded);
    let pooled = fleet.run(Runtime::Pooled);
    assert_eq!(threaded.per_stream.len(), fleet.n_streams);
    assert_eq!(pooled.per_stream.len(), fleet.n_streams);
    let a = discrete(&threaded);
    let b = discrete(&pooled);
    for (si, (ta, tb)) in a.iter().zip(b.iter()).enumerate() {
        assert_eq!(
            ta, tb,
            "stream {si}: threaded and pooled outcomes diverge"
        );
    }
    (threaded, pooled)
}

#[test]
fn threaded_and_pooled_produce_identical_outcomes() {
    let fleet = Fleet {
        n_streams: 4,
        n_tasks: 24,
        // mid threshold: the seeded workload crosses it both ways, so
        // the comparison covers the Exit AND the Transmit paths
        exit_threshold: 0.5,
        cut_elems: 1024,
        link_mbps: 50.0,
        queue_cap: 8,
        cloud: BatchCfg::default(),
    };
    let (threaded, _pooled) = assert_equivalent(&fleet);

    // the workload itself must exercise both verdicts, or the
    // equivalence above is vacuous on one of the two paths
    let agg = threaded.aggregate();
    let exits = agg.tasks.iter().filter(|t| t.exited_early).count();
    assert!(exits > 0, "no early exits — raise exit_threshold coverage");
    assert!(
        exits < agg.tasks.len(),
        "every task exited — nothing crossed the link"
    );
    assert_eq!(agg.tasks.len(), 4 * 24, "no task lost by either engine");
}

#[test]
fn queue_cap_backpressure_surfaces_identically() {
    // cap the link hand-off at ONE in-flight item and slow the link so
    // it saturates: devices must block on admission in both engines,
    // and neither may lose or reorder a task while stalled
    let fleet = Fleet {
        n_streams: 4,
        n_tasks: 12,
        exit_threshold: f64::INFINITY,
        cut_elems: 2048,
        link_mbps: 5.0,
        queue_cap: 1,
        cloud: BatchCfg::default(),
    };
    let (threaded, pooled) = assert_equivalent(&fleet);
    for multi in [&threaded, &pooled] {
        let agg = multi.aggregate();
        assert_eq!(agg.tasks.len(), 4 * 12, "conservation under cap=1");
        assert_eq!(agg.dropped, 0, "no admission control configured");
        // the link really was the bottleneck: its busy time exceeds any
        // single stream's device time by a wide margin
        assert!(
            agg.link.busy > 3.0 * 12.0 * 5e-4,
            "link not saturated (busy {}s) — backpressure untested",
            agg.link.busy
        );
    }
}

/// Under `cloud_sched = "batch"` the two engines may form different
/// batches (formation is wall-clock timing dependent), but every
/// DISCRETE outcome must still be identical — batching may only move
/// completion times, never change what a task computed — and the
/// occupancy histogram must account for every transmitted task
/// exactly once in both engines.
#[test]
fn batched_cloud_keeps_engines_equivalent() {
    let fleet = Fleet {
        n_streams: 4,
        n_tasks: 24,
        exit_threshold: 0.5,
        cut_elems: 1024,
        link_mbps: 50.0,
        queue_cap: 8,
        cloud: BatchCfg {
            policy: CloudPolicy::DynBatch,
            max_batch: 4,
            max_wait: 200e-6,
            slo: f64::INFINITY,
            ..BatchCfg::default()
        },
    };
    let (threaded, pooled) = assert_equivalent(&fleet);
    for (name, multi) in [("threaded", &threaded), ("pooled", &pooled)] {
        let agg = multi.aggregate();
        assert_eq!(agg.tasks.len(), 4 * 24, "{name}: conservation");
        let transmitted =
            agg.tasks.iter().filter(|t| !t.exited_early).count();
        let batched_items: usize = multi
            .batch_occupancy
            .iter()
            .enumerate()
            .map(|(i, &c)| (i + 1) * c as usize)
            .sum();
        assert_eq!(
            batched_items, transmitted,
            "{name}: occupancy histogram must account for every \
             transmitted task exactly once"
        );
    }
}

/// A worker that panics mid-drive must not hang or poison the run: its
/// `PanicGuard` flags the pool down, the sibling workers unwind
/// cleanly, and `run_real` surfaces the fault as an error instead of a
/// deadlocked join. (The pool's lock discipline under this scenario is
/// model-checked in `tests/loom_pool.rs`.)
#[test]
fn pooled_worker_panic_is_contained() {
    struct PanicDevice;
    impl DeviceStage for PanicDevice {
        type Wire = ();
        type Feedback = ();
        type Portable = Self;
        fn dehydrate(self) -> std::result::Result<Self, Self> {
            Ok(self)
        }
        fn rehydrate(portable: Self) -> Self {
            portable
        }
        fn process(
            &mut self,
            _task: &SimTask,
        ) -> anyhow::Result<(DeviceVerdict<()>, f64)> {
            panic!("injected device fault");
        }
        fn poll_process(
            &mut self,
            _task: &SimTask,
        ) -> Option<anyhow::Result<(DeviceVerdict<()>, f64)>> {
            panic!("injected device fault");
        }
    }
    struct NullCloud;
    impl CloudStage for NullCloud {
        type Wire = ();
        type Feedback = ();
        fn process(&mut self, _wire: ()) -> anyhow::Result<(usize, ())> {
            Ok((0, ()))
        }
    }
    let clock = WallClock::new();
    let streams: Vec<(Vec<SimTask>, _)> = (0..2u64)
        .map(|i| {
            let tasks = generate(2, PERIOD, Correlation::Medium, 10, 7 + i);
            (tasks, move || -> anyhow::Result<PanicDevice> {
                Ok(PanicDevice)
            })
        })
        .collect();
    let err = run_real::<PanicDevice, NullCloud, _, _>(
        streams,
        || Ok(NullCloud),
        BandwidthModel::Static(50.0),
        clock,
        RealCfg {
            runtime: Runtime::Pooled,
            queue_cap: 4,
            scheme: "panic".into(),
            model: "sim".into(),
            ..Default::default()
        },
    )
    .expect_err("a panicking worker must fail the run, not hang it");
    assert!(
        format!("{err:#}").contains("worker thread panicked"),
        "unexpected error: {err:#}"
    );
}

/// The work-stealing gate's correctness half: a 10:1 compute-skew
/// fleet must produce IDENTICAL discrete outcomes under the threaded
/// reference, the pinned pooled scheduler (`steal = false`), and the
/// stealing pooled scheduler. Stealing may only move WHERE and WHEN a
/// stream's tasks run — never what they compute. (The throughput half
/// of the gate lives in `coach bench-serve-scale`.)
#[test]
fn skewed_fleet_outcomes_survive_stealing_and_pinning() {
    let fleet = Fleet {
        n_streams: 8,
        n_tasks: 12,
        // mid threshold so both the Exit and the Transmit paths are
        // exercised while streams migrate between workers
        exit_threshold: 0.5,
        cut_elems: 1024,
        link_mbps: 50.0,
        queue_cap: 8,
        cloud: BatchCfg::default(),
    };
    // every 4th stream carries 10x device compute: heavy streams share
    // a home worker under static pinning, so the pinned run convoys
    // exactly where the stealing run load-balances
    let skew: Vec<f64> = (0..fleet.n_streams)
        .map(|i| if i % 4 == 0 { 10.0 } else { 1.0 })
        .collect();
    let threaded = fleet.run_cfg(Runtime::Threaded, true, &skew);
    let pinned = fleet.run_cfg(Runtime::Pooled, false, &skew);
    let stealing = fleet.run_cfg(Runtime::Pooled, true, &skew);
    let a = discrete(&threaded);
    assert_eq!(
        a,
        discrete(&pinned),
        "pinned pooled run diverges from the threaded reference"
    );
    assert_eq!(
        a,
        discrete(&stealing),
        "stealing pooled run diverges from the threaded reference"
    );
    // the comparison must not be vacuous: tasks on both verdict paths,
    // nothing lost, and the pinned run must really not have stolen
    let agg = threaded.aggregate();
    assert_eq!(agg.tasks.len(), 8 * 12, "conservation under skew");
    let exits = agg.tasks.iter().filter(|t| t.exited_early).count();
    assert!(exits > 0 && exits < agg.tasks.len(), "one-sided workload");
    assert_eq!(pinned.steals, 0, "steal=false must never migrate");
    assert_eq!(threaded.steals, 0, "threaded engine has no pool");
}

#[test]
fn pooled_engine_serves_wide_fleets_with_bounded_workers() {
    // 256 streams is ~an order of magnitude past sensible
    // thread-per-stream territory for a unit test; the pooled engine
    // must serve it with worker count <= available cores and lose
    // nothing. (The 10k-stream case is `coach serve-sim --streams
    // 10000 --runtime pooled` / `coach bench-serve-scale`.)
    let fleet = Fleet {
        n_streams: 256,
        n_tasks: 2,
        exit_threshold: f64::INFINITY,
        cut_elems: 256,
        link_mbps: 200.0,
        queue_cap: 8,
        cloud: BatchCfg::default(),
    };
    let multi = fleet.run(Runtime::Pooled);
    assert_eq!(multi.per_stream.len(), 256);
    let agg = multi.aggregate();
    assert_eq!(agg.tasks.len(), 256 * 2, "every task served");
    assert_eq!(agg.dropped, 0);
    for (si, r) in multi.per_stream.iter().enumerate() {
        assert_eq!(r.tasks.len(), 2, "stream {si} incomplete");
        let ids: Vec<usize> = r.tasks.iter().map(|t| t.id).collect();
        assert_eq!(ids, vec![0, 1], "stream {si} ids out of order");
    }
}
