//! END-TO-END VALIDATION DRIVER (ARCHITECTURE.md §Experiment index).
//!
//! Loads the real compiled model artifacts and serves a batched stream
//! of requests through the FULL system — offline partitioning on the
//! measured block profile, threaded device/link/cloud pipeline over the
//! PJRT runtime, semantic-cache warmup, per-task early-exit and
//! adaptive UAQ precision — and reports latency and throughput, with an
//! accuracy audit of early exits against the full fp32 model. Each
//! configuration is ONE `Scenario` description executed by
//! `Scenario::serve`.
//!
//! Run: `cargo run --release --example e2e_serving [n_tasks]`

use coach::model::{topology, CostModel, DeviceProfile};
use coach::partition::{optimize, MeasuredAcc, PartitionConfig};
use coach::runtime::{default_artifact_dir, Engine, Manifest, ModelRuntime};
use coach::scenario::Scenario;
use coach::sim::Correlation;

fn main() -> anyhow::Result<()> {
    let n_tasks: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(240);
    let manifest = Manifest::load(&default_artifact_dir())?;

    for model in ["resnet_mini", "vgg_mini"] {
        println!("=== {model} ===");

        // ---- offline component: measured profile -> strategy ----------
        let cut = {
            let engine = Engine::new(&manifest)?;
            let rt = ModelRuntime::new(&engine, &manifest, model)?;
            let secs = rt.profile_blocks(3)?;
            let g = topology::from_manifest(rt.model, &secs);
            let cost = CostModel::new(
                DeviceProfile::mini_device(6.0),
                DeviceProfile::mini_cloud(),
            );
            let cfg = PartitionConfig { bw_mbps: 20.0, ..Default::default() };
            let acc = MeasuredAcc { table: &manifest.acc, model: model.into() };
            let s = optimize(&g, &cost, &acc, &cfg)?;
            // graph layer k = block k-1 (layer 0 is the input)
            let n_dev = s.n_device_layers();
            let cut = n_dev.saturating_sub(2).min(rt.model.n_cuts() - 1);
            println!(
                "offline: device blocks 0..={cut}, base bits {:?}, objective {:.2} ms",
                s.cuts.iter().map(|c| c.bits).collect::<Vec<_>>(),
                s.eval.objective() * 1e3
            );
            cut
        };

        // the common description: everything below varies policy/fleet
        let base = || {
            Scenario::new(model)
                .named("e2e-serving")
                .cut(cut)
                .device_scale(6.0) // NX-like device:cloud ratio
                .bandwidth_mbps(20.0)
                .period(0.012)
                .correlation(Correlation::High)
                .seed(7)
        };

        // ---- full online pipeline, batched request stream --------------
        for (name, adaptive) in [("COACH", true), ("NoAdjust", false)] {
            let mut sc = base()
                .tasks(n_tasks)
                .audit_every(4); // audit every 4th early exit vs fp32
            if !adaptive {
                sc = sc.policy_static(8, f64::INFINITY);
            }
            let res = sc.serve(&manifest)?;
            let r = &res.report;
            println!(
                "{name:>9}: lat {:6.2} ms (p99 {:6.2}) | {:5.1} it/s | exits {:4.1}% | wire {:6.1} Kb | acc(audited) {:.3}",
                r.avg_latency_ms(),
                r.p99_latency_ms(),
                r.throughput(),
                r.exit_ratio() * 100.0,
                r.avg_wire_kb(),
                r.accuracy()
            );
            println!(
                "           stage util: device {:3.0}% link {:3.0}% cloud {:3.0}% | bubbles {:.2} s",
                r.device.utilization() * 100.0,
                r.link.utilization() * 100.0,
                r.cloud.utilization() * 100.0,
                r.total_bubbles()
            );
        }

        // ---- multi-stream: 4 concurrent users, one shared cloud engine --
        let res = base().tasks(n_tasks / 2).fleet(4).serve(&manifest)?;
        for (i, r) in res.per_stream.iter().enumerate() {
            println!(
                "  stream {i}: lat {:6.2} ms | {:5.1} it/s | exits {:4.1}%",
                r.avg_latency_ms(),
                r.throughput(),
                r.exit_ratio() * 100.0
            );
        }
        println!(
            "  4 streams: aggregate {:.1} it/s (one shared cloud engine)",
            res.report.throughput()
        );
    }
    println!("\ne2e_serving OK");
    Ok(())
}
