//! Multi-user serving scenario: N concurrent device streams (each a
//! "user" with its own arrival process and policy state) share ONE
//! cloud stage through the FIFO link — the contention regime of
//! production end-cloud serving (PICO/CoEdge-style multi-device
//! pipelines).
//!
//! ONE scenario description drives BOTH substrates here: the
//! multi-stream DES (virtual time, instant) and the wall-clock threaded
//! driver with simulated compute (real threads, no compiled artifacts
//! required). The same driver with PJRT stages backs
//! `coach serve --streams N` and `coach run --real`.
//!
//! Run: `cargo run --release --example multi_user [n_streams]`

use coach::metrics::Table;
use coach::scenario::Scenario;

fn main() -> anyhow::Result<()> {
    let n_streams: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(4);

    let scenario = |fleet: usize| {
        Scenario::new("vgg16")
            .named("multi-user")
            .bandwidth_mbps(40.0)
            .tasks(40)
            .period(0.008)
            .n_classes(20)
            .seed(99)
            .fleet(fleet)
    };

    let mut table = Table::new(&[
        "fleet",
        "driver",
        "aggregate it/s",
        "avg latency ms",
        "p99 ms",
        "cloud util %",
    ]);

    for fleet in [1, n_streams] {
        // virtual time first: the DES predicts the contention …
        let des = scenario(fleet).simulate_fleet()?.aggregate();
        // … and the SAME description then runs on real threads with
        // busy-sleep stages priced from the same analytic plan.
        let wall = scenario(fleet).serve_sim()?;
        let wall_agg = wall.aggregate();
        for (driver, agg) in [("DES", &des), ("wall-clock", &wall_agg)] {
            table.row(vec![
                format!("{fleet} stream(s)"),
                driver.to_string(),
                format!("{:.1}", agg.throughput()),
                format!("{:.2}", agg.avg_latency_ms()),
                format!("{:.2}", agg.p99_latency_ms()),
                format!("{:.0}", agg.cloud.utilization() * 100.0),
            ]);
        }
        if fleet > 1 {
            for (i, r) in wall.per_stream.iter().enumerate() {
                println!(
                    "  stream {i} (wall): {:5.1} it/s | lat {:6.2} ms | exits {:4.1}%",
                    r.throughput(),
                    r.avg_latency_ms(),
                    r.exit_ratio() * 100.0
                );
            }
        }
    }

    println!("\n{n_streams}-user fleet vs single user, one description, two drivers:");
    println!("{}", table.render());
    println!("multi_user OK");
    Ok(())
}
