//! Multi-user serving scenario: N concurrent device streams (each a
//! "user" with its own arrival process and policy state) share ONE
//! cloud stage through the FIFO link — the contention regime of
//! production end-cloud serving (PICO/CoEdge-style multi-device
//! pipelines).
//!
//! Runs on the wall-clock driver with simulated compute, so it works on
//! any machine — no compiled artifacts required. The same driver with
//! PJRT stages backs `coach serve --streams N` (see
//! coordinator::server).
//!
//! Run: `cargo run --release --example multi_user [n_streams]`

use coach::metrics::Table;
use coach::model::{CostModel, DeviceProfile};
use coach::network::BandwidthModel;
use coach::pipeline::driver::{run_real, RealCfg, SimCloud, SimDevice};
use coach::pipeline::{StaticPolicy, WallClock};
use coach::sim::{generate, Correlation, SimTask};

fn main() -> anyhow::Result<()> {
    let n_streams: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(4);
    let n_tasks = 60;
    let period = 0.008;

    let mut table = Table::new(&[
        "fleet",
        "aggregate it/s",
        "avg latency ms",
        "p99 ms",
        "cloud util %",
    ]);

    for fleet in [1, n_streams] {
        let clock = WallClock::new();
        let streams: Vec<(Vec<SimTask>, _)> = (0..fleet)
            .map(|i| {
                let tasks = generate(
                    n_tasks,
                    period,
                    Correlation::Medium,
                    20,
                    99 + i as u64,
                );
                let bw = BandwidthModel::Static(40.0);
                let cost = CostModel::new(
                    DeviceProfile::jetson_nx(),
                    DeviceProfile::cloud_a6000(),
                );
                let factory = move || -> anyhow::Result<SimDevice<StaticPolicy>> {
                    Ok(SimDevice {
                        policy: StaticPolicy { bits: 8, exit_threshold: 0.8 },
                        t_e: 0.006,
                        bw,
                        clock,
                        elems: 4096,
                        cost,
                    })
                };
                (tasks, factory)
            })
            .collect();
        let multi = run_real::<SimDevice<StaticPolicy>, SimCloud, _, _>(
            streams,
            || Ok(SimCloud { t_c: 0.0012 }),
            BandwidthModel::Static(40.0),
            clock,
            RealCfg { model: "sim".into(), ..Default::default() },
        )?;
        let agg = multi.aggregate();
        table.row(vec![
            format!("{fleet} stream(s)"),
            format!("{:.1}", agg.throughput()),
            format!("{:.2}", agg.avg_latency_ms()),
            format!("{:.2}", agg.p99_latency_ms()),
            format!("{:.0}", agg.cloud.utilization() * 100.0),
        ]);
        if fleet > 1 {
            for (i, r) in multi.per_stream.iter().enumerate() {
                println!(
                    "  stream {i}: {:5.1} it/s | lat {:6.2} ms | exits {:4.1}%",
                    r.throughput(),
                    r.avg_latency_ms(),
                    r.exit_ratio() * 100.0
                );
            }
        }
    }

    println!("\n{n_streams}-user fleet vs single user (simulated compute):");
    println!("{}", table.render());
    println!("multi_user OK");
    Ok(())
}
