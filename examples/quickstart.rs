//! Quickstart: the five-minute tour of COACH's public API.
//!
//! 1. load the AOT artifacts (`make artifacts` first),
//! 2. run one collaborative inference by hand (device prefix -> UAQ
//!    transmission round trip -> cloud suffix),
//! 3. let the offline component pick the partition + precision,
//! 4. describe a paper-scale experiment ONCE as a `Scenario` and race
//!    COACH against the four baselines through the DES.
//!
//! Run: `cargo run --release --example quickstart`

use coach::baselines::Scheme;
use coach::model::{topology, CostModel, DeviceProfile};
use coach::partition::{optimize, MeasuredAcc, PartitionConfig};
use coach::runtime::{default_artifact_dir, Engine, Manifest, ModelRuntime, Tensor};
use coach::scenario::Scenario;

fn main() -> anyhow::Result<()> {
    // ---- 1. artifacts -------------------------------------------------
    let manifest = Manifest::load(&default_artifact_dir())?;
    println!(
        "loaded manifest: models {:?}, {} uaq codecs, {} gap extractors",
        manifest.models.keys().collect::<Vec<_>>(),
        manifest.uaq.len(),
        manifest.gap.len()
    );
    let engine = Engine::new(&manifest)?;
    let rt = ModelRuntime::new(&engine, &manifest, "resnet_mini")?;

    // ---- 2. one collaborative inference, by hand ----------------------
    let patterns = manifest.read_f32(&manifest.patterns.file)?;
    let isz: usize = manifest.input_shape.iter().product();
    let x = Tensor::new(manifest.input_shape.clone(), patterns[..isz].to_vec())?;

    let full = rt.run_blocks(0, rt.model.blocks.len(), &x)?;
    let cut = 2;
    let act = rt.run_device(cut, &x)?; // end device: blocks 0..=2
    let feat = rt.gap_feature(&act)?; // task feature for the cache
    let wire = rt.uaq_roundtrip(&act, 4)?; // 4-bit UAQ codec
    let logits = rt.run_cloud(cut, &wire)?; // cloud: remaining blocks
    println!(
        "single task: fp32 label {}, 4-bit collaborative label {} (feature dim {})",
        full.argmax(),
        logits.argmax(),
        feat.elems()
    );

    // ---- 3. offline component on the measured mini model --------------
    let secs = rt.profile_blocks(3)?;
    let g = topology::from_manifest(rt.model, &secs);
    // mini-model cost scale: CPU plays the cloud, device is 6x slower
    let mini_cost = CostModel::new(
        DeviceProfile::mini_device(6.0),
        DeviceProfile::mini_cloud(),
    );
    let cfg = PartitionConfig { bw_mbps: 20.0, ..Default::default() };
    let acc = MeasuredAcc { table: &manifest.acc, model: "resnet_mini".into() };
    let strat = optimize(&g, &mini_cost, &acc, &cfg)?;
    println!(
        "offline strategy (measured profile): device layers {}/{}, cut bits {:?}, objective {:.2} ms",
        strat.n_device_layers(),
        g.n(),
        strat.cuts.iter().map(|c| c.bits).collect::<Vec<_>>(),
        strat.eval.objective() * 1e3
    );

    // ---- 4. one Scenario, five schemes, through the DES ----------------
    // A Scenario is the single front door: model + device + network +
    // workload described once, then simulated (or served — see
    // `coach run scenarios/table1_cell.toml`).
    println!("\nResNet101 @ 20 Mbps on Jetson NX, 300 tasks under common load:");
    for scheme in Scheme::ALL {
        let plan = Scenario::new("resnet101")
            .scheme(scheme)
            .bandwidth_mbps(20.0)
            .tasks(300)
            .sustainable_load()
            .drop_after_periods(6.0)
            .compile()?; // plan once; run() reuses the compiled plan
        let r = plan.run();
        println!(
            "  {:>6}: plan obj {:6.2} ms | lat {:7.2} ms | {:5.1} it/s | exits {:4.1}% | bubbles {:5.2} s",
            scheme.name(),
            plan.strategy.eval.objective() * 1e3,
            r.avg_latency_ms(),
            r.throughput(),
            r.exit_ratio() * 100.0,
            r.total_bubbles()
        );
    }
    println!("\nquickstart OK");
    Ok(())
}
