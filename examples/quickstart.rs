//! Quickstart: the five-minute tour of COACH's public API.
//!
//! 1. load the AOT artifacts (`make artifacts` first),
//! 2. run one collaborative inference by hand (device prefix -> UAQ
//!    transmission round trip -> cloud suffix),
//! 3. let the offline component pick the partition + precision,
//! 4. compare COACH against the four baselines on the paper-scale
//!    ResNet101 cost model.
//!
//! Run: `cargo run --release --example quickstart`

use coach::baselines::Scheme;
use coach::model::{topology, CostModel, DeviceProfile};
use coach::partition::{optimize, AnalyticAcc, MeasuredAcc, PartitionConfig};
use coach::runtime::{default_artifact_dir, Engine, Manifest, ModelRuntime, Tensor};

fn main() -> anyhow::Result<()> {
    // ---- 1. artifacts -------------------------------------------------
    let manifest = Manifest::load(&default_artifact_dir())?;
    println!(
        "loaded manifest: models {:?}, {} uaq codecs, {} gap extractors",
        manifest.models.keys().collect::<Vec<_>>(),
        manifest.uaq.len(),
        manifest.gap.len()
    );
    let engine = Engine::new(&manifest)?;
    let rt = ModelRuntime::new(&engine, &manifest, "resnet_mini")?;

    // ---- 2. one collaborative inference, by hand ----------------------
    let patterns = manifest.read_f32(&manifest.patterns.file)?;
    let isz: usize = manifest.input_shape.iter().product();
    let x = Tensor::new(manifest.input_shape.clone(), patterns[..isz].to_vec())?;

    let full = rt.run_blocks(0, rt.model.blocks.len(), &x)?;
    let cut = 2;
    let act = rt.run_device(cut, &x)?; // end device: blocks 0..=2
    let feat = rt.gap_feature(&act)?; // task feature for the cache
    let wire = rt.uaq_roundtrip(&act, 4)?; // 4-bit UAQ codec
    let logits = rt.run_cloud(cut, &wire)?; // cloud: remaining blocks
    println!(
        "single task: fp32 label {}, 4-bit collaborative label {} (feature dim {})",
        full.argmax(),
        logits.argmax(),
        feat.elems()
    );

    // ---- 3. offline component on the measured mini model --------------
    let secs = rt.profile_blocks(3)?;
    let g = topology::from_manifest(rt.model, &secs);
    // mini-model cost scale: CPU plays the cloud, device is 6x slower
    let mini_cost = CostModel::new(
        DeviceProfile::mini_device(6.0),
        DeviceProfile::mini_cloud(),
    );
    let cfg = PartitionConfig { bw_mbps: 20.0, ..Default::default() };
    let acc = MeasuredAcc { table: &manifest.acc, model: "resnet_mini".into() };
    let strat = optimize(&g, &mini_cost, &acc, &cfg)?;
    println!(
        "offline strategy (measured profile): device layers {}/{}, cut bits {:?}, objective {:.2} ms",
        strat.n_device_layers(),
        g.n(),
        strat.cuts.iter().map(|c| c.bits).collect::<Vec<_>>(),
        strat.eval.objective() * 1e3
    );

    // ---- 4. COACH vs baselines on the paper-scale DAG -----------------
    let big = topology::resnet101();
    let cost =
        CostModel::new(DeviceProfile::jetson_nx(), DeviceProfile::cloud_a6000());
    println!("\nResNet101 @ 20 Mbps on Jetson NX (paper-scale cost model):");
    for scheme in Scheme::ALL {
        let s = scheme.plan(&big, &cost, &AnalyticAcc, &cfg)?;
        println!(
            "  {:>6}: latency {:6.2} ms | max stage {:6.2} ms | bubbles {:6.2} ms | Eq.6 objective {:6.2} ms",
            scheme.name(),
            s.eval.latency * 1e3,
            s.eval.max_stage() * 1e3,
            (s.eval.b_c + s.eval.b_t) * 1e3,
            s.eval.objective() * 1e3
        );
    }
    println!("\nquickstart OK");
    Ok(())
}
