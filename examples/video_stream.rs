//! Video-stream scenario (the paper's UCF101 motivation): a camera
//! produces temporally-correlated frames; COACH's context-aware cache
//! converts that correlation into early exits and cheaper transmissions.
//!
//! Serves the same stream at all three correlation levels and prints a
//! Table II-style comparison on the REAL compiled pipeline.
//!
//! Run: `cargo run --release --example video_stream [n_tasks]`

use coach::coordinator::server::{serve, SchemePolicy, ServeCfg};
use coach::metrics::Table;
use coach::network::BandwidthModel;
use coach::runtime::{default_artifact_dir, Manifest};
use coach::sim::Correlation;

fn main() -> anyhow::Result<()> {
    let n_tasks: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(200);
    let manifest = Manifest::load(&default_artifact_dir())?;
    let model = "resnet_mini";
    let m = manifest.model(model)?;
    let cut = (m.blocks.len() - 1) / 2;

    let mut table = Table::new(&[
        "stream",
        "exit %",
        "latency ms",
        "wire Kb/task",
        "throughput it/s",
    ]);

    for (label, corr, policy) in [
        ("no-adjust", Correlation::High, SchemePolicy::no_adjust()),
        ("low corr (random frames)", Correlation::Low, SchemePolicy::coach()),
        ("medium corr (random videos)", Correlation::Medium, SchemePolicy::coach()),
        ("high corr (sequential video)", Correlation::High, SchemePolicy::coach()),
    ] {
        let cfg = ServeCfg {
            model: model.to_string(),
            cut,
            policy,
            device_scale: 6.0,
            bw: BandwidthModel::Static(20.0),
            period: 0.012,
            n_tasks,
            correlation: corr,
            eps: 0.005,
            seed: 21,
            audit_every: 0,
            n_streams: 1,
        };
        let res = serve(&manifest, &cfg)?;
        let r = &res.report;
        table.row(vec![
            label.to_string(),
            format!("{:.1}", r.exit_ratio() * 100.0),
            format!("{:.2}", r.avg_latency_ms()),
            format!("{:.1}", r.avg_wire_kb()),
            format!("{:.1}", r.throughput()),
        ]);
    }
    println!("{model} @ 20 Mbps, NX-like device (real pipeline):");
    println!("{}", table.render());
    println!("video_stream OK");
    Ok(())
}
