//! Video-stream scenario (the paper's UCF101 motivation): a camera
//! produces temporally-correlated frames; COACH's context-aware cache
//! converts that correlation into early exits and cheaper transmissions.
//!
//! Serves the same `Scenario` description at all three correlation
//! levels and prints a Table II-style comparison on the REAL compiled
//! pipeline (`Scenario::serve` -> coordinator::server).
//!
//! Run: `cargo run --release --example video_stream [n_tasks]`

use coach::metrics::Table;
use coach::runtime::{default_artifact_dir, Manifest};
use coach::scenario::Scenario;
use coach::sim::Correlation;

fn main() -> anyhow::Result<()> {
    let n_tasks: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(200);
    let manifest = Manifest::load(&default_artifact_dir())?;
    let model = "resnet_mini";

    let mut table = Table::new(&[
        "stream",
        "exit %",
        "latency ms",
        "wire Kb/task",
        "throughput it/s",
    ]);

    for (label, corr, adaptive) in [
        ("no-adjust", Correlation::High, false),
        ("low corr (random frames)", Correlation::Low, true),
        ("medium corr (random videos)", Correlation::Medium, true),
        ("high corr (sequential video)", Correlation::High, true),
    ] {
        let mut sc = Scenario::new(model)
            .named("video-stream")
            .device_scale(6.0)
            .bandwidth_mbps(20.0)
            .period(0.012)
            .tasks(n_tasks)
            .correlation(corr)
            .seed(21);
        if !adaptive {
            sc = sc.policy_static(8, f64::INFINITY);
        }
        let res = sc.serve(&manifest)?;
        let r = &res.report;
        table.row(vec![
            label.to_string(),
            format!("{:.1}", r.exit_ratio() * 100.0),
            format!("{:.2}", r.avg_latency_ms()),
            format!("{:.1}", r.avg_wire_kb()),
            format!("{:.1}", r.throughput()),
        ]);
    }
    println!("{model} @ 20 Mbps, NX-like device (real pipeline):");
    println!("{}", table.render());
    println!("video_stream OK");
    Ok(())
}
