//! Dynamic-network scenario (the paper's Fig. 5 motivation): bandwidth
//! steps down mid-run; COACH's per-task quantization adjustment keeps
//! the pipeline stable while a fixed-precision pipeline stalls.
//!
//! Runs the REAL compiled pipeline against a 20 -> 10 -> 5 Mbps step
//! trace and prints per-phase latency for COACH vs the NoAdjust
//! configuration.
//!
//! Run: `cargo run --release --example dynamic_network [n_tasks]`

use coach::coordinator::server::{serve, SchemePolicy, ServeCfg};
use coach::metrics::Table;
use coach::network::{BandwidthModel, Trace};
use coach::runtime::{default_artifact_dir, Manifest};
use coach::sim::Correlation;

fn main() -> anyhow::Result<()> {
    let n_tasks: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(300);
    let manifest = Manifest::load(&default_artifact_dir())?;
    let model = "vgg_mini";
    let m = manifest.model(model)?;
    let cut = (m.blocks.len() - 1) / 2;

    // step the bandwidth down at 1/3 and 2/3 of the run
    let span = n_tasks as f64 * 0.012;
    let trace = Trace {
        steps: vec![(0.0, 20.0), (span / 3.0, 10.0), (2.0 * span / 3.0, 5.0)],
    };

    let mut table = Table::new(&[
        "policy",
        "latency ms",
        "p99 ms",
        "throughput it/s",
        "wire Kb/task",
        "exit %",
    ]);
    for (name, policy) in [
        ("COACH (adaptive)", SchemePolicy::coach()),
        ("NoAdjust (fixed 8-bit)", SchemePolicy::no_adjust()),
    ] {
        let cfg = ServeCfg {
            model: model.to_string(),
            cut,
            policy,
            device_scale: 6.0,
            bw: BandwidthModel::Stepped(trace.clone()),
            period: 0.012,
            n_tasks,
            correlation: Correlation::Medium,
            eps: 0.005,
            seed: 33,
            audit_every: 0,
            n_streams: 1,
        };
        let res = serve(&manifest, &cfg)?;
        let r = &res.report;
        table.row(vec![
            name.to_string(),
            format!("{:.2}", r.avg_latency_ms()),
            format!("{:.2}", r.p99_latency_ms()),
            format!("{:.1}", r.throughput()),
            format!("{:.1}", r.avg_wire_kb()),
            format!("{:.1}", r.exit_ratio() * 100.0),
        ]);
    }
    println!("{model}, bandwidth 20 -> 10 -> 5 Mbps mid-run (real pipeline):");
    println!("{}", table.render());
    println!("dynamic_network OK");
    Ok(())
}
