//! Dynamic-network scenario (the paper's Fig. 5 motivation): bandwidth
//! steps down mid-run; COACH's per-task quantization adjustment keeps
//! the pipeline stable while a fixed-precision pipeline stalls.
//!
//! Runs the REAL compiled pipeline against a 20 -> 10 -> 5 Mbps step
//! trace and prints per-phase latency for COACH vs the NoAdjust
//! configuration — one `Scenario` description per policy, executed by
//! `Scenario::serve`.
//!
//! Run: `cargo run --release --example dynamic_network [n_tasks]`

use coach::metrics::Table;
use coach::network::{BandwidthModel, Trace};
use coach::runtime::{default_artifact_dir, Manifest};
use coach::scenario::Scenario;

fn main() -> anyhow::Result<()> {
    let n_tasks: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(300);
    let manifest = Manifest::load(&default_artifact_dir())?;
    let model = "vgg_mini";

    // step the bandwidth down at 1/3 and 2/3 of the run
    let span = n_tasks as f64 * 0.012;
    let trace = Trace {
        steps: vec![(0.0, 20.0), (span / 3.0, 10.0), (2.0 * span / 3.0, 5.0)],
    };

    let mut table = Table::new(&[
        "policy",
        "latency ms",
        "p99 ms",
        "throughput it/s",
        "wire Kb/task",
        "exit %",
    ]);
    for (name, adaptive) in
        [("COACH (adaptive)", true), ("NoAdjust (fixed 8-bit)", false)]
    {
        let mut sc = Scenario::new(model)
            .named("dynamic-network")
            .device_scale(6.0)
            .bandwidth(BandwidthModel::Stepped(trace.clone()))
            .period(0.012)
            .tasks(n_tasks)
            .seed(33);
        if !adaptive {
            sc = sc.policy_static(8, f64::INFINITY);
        }
        let res = sc.serve(&manifest)?;
        let r = &res.report;
        table.row(vec![
            name.to_string(),
            format!("{:.2}", r.avg_latency_ms()),
            format!("{:.2}", r.p99_latency_ms()),
            format!("{:.1}", r.throughput()),
            format!("{:.1}", r.avg_wire_kb()),
            format!("{:.1}", r.exit_ratio() * 100.0),
        ]);
    }
    println!("{model}, bandwidth 20 -> 10 -> 5 Mbps mid-run (real pipeline):");
    println!("{}", table.render());
    println!("dynamic_network OK");
    Ok(())
}
