//! Repo-local task runner (`cargo xtask <task>`), following the
//! cargo-xtask pattern: a plain workspace binary, no external deps, so
//! it builds anywhere the crate does.
//!
//! The one task so far is `lint` — a line-based invariant linter for
//! the correctness contracts that rustc cannot express (see
//! ARCHITECTURE.md §Correctness & static analysis):
//!
//! * `wall-clock` — `std::time::{Instant, SystemTime}` may only appear
//!   under `serve/`, `coordinator/`, `bench/`, or `runtime/`. Everything
//!   else (DES, planner, metrics, quant) must stay virtual-time pure so
//!   results are reproducible and Miri-runnable. The sanctioned wrapper
//!   (`pipeline::stage::WallClock`) carries `// xtask: allow(wall-clock)`
//!   markers.
//! * `map-order` — no `HashMap` under `serve/`, `metrics/`, or in
//!   `pipeline/batch.rs`: stream state, report assembly, and cloud
//!   batch admission feed BENCH json, and randomized iteration order
//!   there breaks run-to-run byte-identity
//!   (`rust/tests/determinism.rs` is the runtime half of this lint).
//! * `unwrap-free` — no `.unwrap()` / `.expect(` in `serve/pool.rs`:
//!   a panicking worker must reach `PanicGuard::drop`, and the guard
//!   itself must never double-panic on a poisoned lock. Fallible access
//!   goes through `Pool::lock_core` / `let … else` instead.
//! * `loom-shim` — the model-checked modules (`serve/pool.rs`,
//!   `serve/sched.rs`, `serve/timer.rs`) must not import `std::sync`
//!   directly; they go through `crate::util::sync` so `--cfg loom`
//!   swaps in the checker's primitives.
//!
//! Lines inside `mod tests` blocks are exempt, as are comment lines and
//! lines carrying an `// xtask: allow(<lint>)` marker. The linter is
//! deliberately textual — it lints INTENT at the import/call-site
//! level, not semantics — which keeps it dependency-free and fast
//! enough to run in the main CI job before the build.

use std::env;
use std::fs;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

/// One lint hit: file (repo-relative), 1-based line, lint name, detail.
#[derive(Debug, PartialEq)]
struct Violation {
    file: String,
    line: usize,
    lint: &'static str,
    msg: String,
}

impl Violation {
    fn render(&self) -> String {
        format!(
            "rust/src/{}:{}: [{}] {}",
            self.file, self.line, self.lint, self.msg
        )
    }
}

/// `word` appears in `line` as a standalone token (not as a substring
/// of a longer identifier — `Instantaneous` must not trip `Instant`).
fn has_word(line: &str, word: &str) -> bool {
    let bytes = line.as_bytes();
    let mut start = 0;
    while let Some(pos) = line[start..].find(word) {
        let i = start + pos;
        let j = i + word.len();
        let before_ok = i == 0 || {
            let c = bytes[i - 1];
            !c.is_ascii_alphanumeric() && c != b'_'
        };
        let after_ok = j == bytes.len() || {
            let c = bytes[j];
            !c.is_ascii_alphanumeric() && c != b'_'
        };
        if before_ok && after_ok {
            return true;
        }
        start = j;
    }
    false
}

fn allowed(line: &str, lint: &str) -> bool {
    line.contains(&format!("xtask: allow({lint})"))
}

/// Net `{` minus `}` on one line. Naive about braces inside string
/// literals — acceptable for tracking `mod tests` extents, which in
/// this tree close at column zero.
fn net_braces(line: &str) -> isize {
    line.bytes().fold(0, |acc, b| match b {
        b'{' => acc + 1,
        b'}' => acc - 1,
        _ => acc,
    })
}

/// Directories (relative to `rust/src`) where wall-clock time is part
/// of the module's job.
const WALL_CLOCK_ALLOWED_DIRS: &[&str] =
    &["serve/", "coordinator/", "bench/", "runtime/"];

/// Files compiled against `crate::util::sync` (the loom shim).
const LOOM_SHIMMED: &[&str] =
    &["serve/pool.rs", "serve/sched.rs", "serve/timer.rs"];

/// Lint one source file. `rel` is the path relative to `rust/src`,
/// `/`-separated. Pure function of its inputs so the unit tests can
/// feed seeded violations without touching the filesystem.
fn lint_file(rel: &str, src: &str) -> Vec<Violation> {
    let wall_clock_scoped =
        !WALL_CLOCK_ALLOWED_DIRS.iter().any(|d| rel.starts_with(d));
    let map_order_scoped = rel.starts_with("serve/")
        || rel.starts_with("metrics/")
        || rel == "pipeline/batch.rs";
    let unwrap_scoped = rel == "serve/pool.rs";
    let loom_scoped = LOOM_SHIMMED.contains(&rel);

    let mut out = Vec::new();
    let mut in_tests = false;
    let mut tests_depth: isize = 0;
    for (idx, line) in src.lines().enumerate() {
        let n = idx + 1;
        let trimmed = line.trim_start();

        // `mod tests` blocks are exempt from every lint: tests may
        // unwrap, measure wall time, and use std primitives freely.
        if in_tests {
            tests_depth += net_braces(line);
            if tests_depth <= 0 {
                in_tests = false;
            }
            continue;
        }
        if (trimmed.starts_with("mod tests") || trimmed.starts_with("pub mod tests"))
            && !trimmed.ends_with(';')
        {
            in_tests = true;
            tests_depth = net_braces(line);
            if tests_depth <= 0 {
                in_tests = false; // one-line `mod tests {}` (unlikely)
            }
            continue;
        }

        // comments document, they don't execute
        if trimmed.starts_with("//") {
            continue;
        }

        if wall_clock_scoped
            && (has_word(line, "Instant") || has_word(line, "SystemTime"))
            && !allowed(line, "wall-clock")
        {
            out.push(Violation {
                file: rel.to_string(),
                line: n,
                lint: "wall-clock",
                msg: "std::time::{Instant, SystemTime} outside serve/, \
                      coordinator/, bench/, runtime/ — use the virtual \
                      clock, or mark the sanctioned wrapper with \
                      `// xtask: allow(wall-clock)`"
                    .into(),
            });
        }

        if map_order_scoped
            && has_word(line, "HashMap")
            && !allowed(line, "map-order")
        {
            out.push(Violation {
                file: rel.to_string(),
                line: n,
                lint: "map-order",
                msg: "HashMap in a report-assembly path — randomized \
                      iteration order breaks BENCH json determinism; \
                      use BTreeMap (see rust/tests/determinism.rs)"
                    .into(),
            });
        }

        if unwrap_scoped
            && (line.contains(".unwrap()") || line.contains(".expect("))
            && !allowed(line, "unwrap-free")
        {
            out.push(Violation {
                file: rel.to_string(),
                line: n,
                lint: "unwrap-free",
                msg: "unwrap()/expect() in the pooled worker path — a \
                      double panic skips PanicGuard; use \
                      Pool::lock_core or `let … else`"
                    .into(),
            });
        }

        if loom_scoped
            && line.contains("std::sync")
            && !allowed(line, "loom-shim")
        {
            out.push(Violation {
                file: rel.to_string(),
                line: n,
                lint: "loom-shim",
                msg: "direct std::sync import in a loom-shimmed module \
                      — import from crate::util::sync so `--cfg loom` \
                      model checking covers this code"
                    .into(),
            });
        }
    }
    out
}

/// Recursively collect `.rs` files under `dir`, sorted for stable
/// output order.
fn rust_sources(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    let mut entries: Vec<_> =
        fs::read_dir(dir)?.collect::<Result<Vec<_>, _>>()?;
    entries.sort_by_key(|e| e.path());
    for e in entries {
        let p = e.path();
        if p.is_dir() {
            rust_sources(&p, out)?;
        } else if p.extension().is_some_and(|x| x == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

/// Lint every file under `rust/src`. Returns (files scanned, hits).
fn lint_tree(src_root: &Path) -> std::io::Result<(usize, Vec<Violation>)> {
    let mut files = Vec::new();
    rust_sources(src_root, &mut files)?;
    let mut all = Vec::new();
    for p in &files {
        let rel = p
            .strip_prefix(src_root)
            .expect("collected under src_root")
            .to_string_lossy()
            .replace('\\', "/");
        let src = fs::read_to_string(p)?;
        all.extend(lint_file(&rel, &src));
    }
    Ok((files.len(), all))
}

fn repo_root() -> PathBuf {
    // xtask/ sits directly under the workspace root
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("xtask has a parent dir")
        .to_path_buf()
}

fn run_lint() -> ExitCode {
    let src_root = repo_root().join("rust").join("src");
    match lint_tree(&src_root) {
        Ok((n_files, hits)) if hits.is_empty() => {
            println!("xtask lint: OK ({n_files} files, 4 lints)");
            ExitCode::SUCCESS
        }
        Ok((_, hits)) => {
            for v in &hits {
                eprintln!("{}", v.render());
            }
            eprintln!("xtask lint: {} violation(s)", hits.len());
            ExitCode::FAILURE
        }
        Err(e) => {
            eprintln!("xtask lint: io error: {e}");
            ExitCode::FAILURE
        }
    }
}

fn main() -> ExitCode {
    let args: Vec<String> = env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("lint") => run_lint(),
        Some(other) => {
            eprintln!("xtask: unknown task '{other}' (tasks: lint)");
            ExitCode::from(2)
        }
        None => {
            eprintln!("usage: cargo xtask <task>\n\ntasks:\n  lint  run the invariant linter over rust/src");
            ExitCode::from(2)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lints(v: &[Violation]) -> Vec<(&'static str, usize)> {
        v.iter().map(|x| (x.lint, x.line)).collect()
    }

    // -- seeded violations: each invariant must be caught -------------

    #[test]
    fn wall_clock_violation_is_caught() {
        let src = "use std::time::Instant;\nfn f() -> f64 {\n    let t0 = Instant::now();\n    t0.elapsed().as_secs_f64()\n}\n";
        let v = lint_file("pipeline/evq.rs", src);
        assert_eq!(lints(&v), [("wall-clock", 1), ("wall-clock", 3)]);
    }

    #[test]
    fn system_time_is_caught_too() {
        let v = lint_file(
            "metrics/mod.rs",
            "let now = std::time::SystemTime::now();\n",
        );
        assert_eq!(lints(&v), [("wall-clock", 1)]);
    }

    #[test]
    fn map_order_violation_is_caught() {
        let src = "use std::collections::HashMap;\nfn report() {\n    let m: HashMap<usize, f64> = HashMap::new();\n    let _ = m;\n}\n";
        let v = lint_file("serve/pool.rs", src);
        assert_eq!(lints(&v), [("map-order", 1), ("map-order", 3)]);
        // the cloud batcher picks admission sets that feed report
        // assembly — same determinism contract
        let v = lint_file("pipeline/batch.rs", src);
        assert_eq!(lints(&v), [("map-order", 1), ("map-order", 3)]);
    }

    #[test]
    fn unwrap_violation_is_caught() {
        let src = "fn worker(core: &Mutex<u8>) {\n    let g = core.lock().unwrap();\n    let v = compute().expect(\"must\");\n    let _ = (g, v);\n}\n";
        let v = lint_file("serve/pool.rs", src);
        assert_eq!(lints(&v), [("unwrap-free", 2), ("unwrap-free", 3)]);
    }

    #[test]
    fn loom_shim_violation_is_caught() {
        for f in super::LOOM_SHIMMED {
            let v = lint_file(f, "use std::sync::{Arc, Mutex};\n");
            assert_eq!(lints(&v), [("loom-shim", 1)], "{f}");
        }
    }

    // -- exemptions ----------------------------------------------------

    #[test]
    fn wall_clock_allowed_dirs_are_exempt() {
        for rel in [
            "serve/threaded.rs",
            "coordinator/server.rs",
            "bench/serve_scale.rs",
            "runtime/executor.rs",
        ] {
            let v = lint_file(rel, "let t0 = Instant::now();\n");
            assert!(v.is_empty(), "{rel}: {v:?}");
        }
    }

    #[test]
    fn allow_marker_suppresses_each_lint() {
        let cases = [
            (
                "pipeline/stage.rs",
                "    t0: Instant, // xtask: allow(wall-clock)\n",
            ),
            (
                "serve/pool.rs",
                "use std::collections::HashMap; // xtask: allow(map-order)\n",
            ),
            (
                "serve/pool.rs",
                "let g = m.lock().unwrap(); // xtask: allow(unwrap-free)\n",
            ),
            (
                "serve/timer.rs",
                "use std::sync::Arc; // xtask: allow(loom-shim)\n",
            ),
        ];
        for (rel, src) in cases {
            assert!(lint_file(rel, src).is_empty(), "{rel}: {src}");
        }
    }

    #[test]
    fn comments_and_longer_identifiers_do_not_trip() {
        // doc comment mentioning Instant; identifier containing it
        let src = "/// `Instant`-based timing is banned here.\nstruct InstantaneousRate(f64);\n// std::sync is shimmed\n";
        assert!(lint_file("network/bandwidth.rs", src).is_empty());
        assert!(lint_file("serve/timer.rs", src).is_empty());
    }

    #[test]
    fn unwrap_or_else_is_not_unwrap() {
        let src = "let g = m.lock().unwrap_or_else(|p| p.into_inner());\nlet v = o.unwrap_or_default();\n";
        assert!(lint_file("serve/pool.rs", src).is_empty());
    }

    #[test]
    fn mod_tests_blocks_are_exempt() {
        let src = "fn prod() {}\n\nmod tests {\n    fn t() {\n        let g = m.lock().unwrap();\n        let t0 = Instant::now();\n        let m: HashMap<u8, u8> = HashMap::new();\n        use std::sync::Arc;\n    }\n}\n";
        assert!(lint_file("serve/pool.rs", src).is_empty());
        // ...but code AFTER the tests block is linted again
        let src2 = format!("{src}\nfn late() {{ let g = m.lock().unwrap(); }}\n");
        let v = lint_file("serve/pool.rs", &src2);
        assert_eq!(lints(&v), [("unwrap-free", 12)]);
    }

    #[test]
    fn mod_tests_declaration_without_body_does_not_swallow_file() {
        let src = "mod tests;\nlet g = m.lock().unwrap();\n";
        let v = lint_file("serve/pool.rs", src);
        assert_eq!(lints(&v), [("unwrap-free", 2)]);
    }

    #[test]
    fn out_of_scope_files_are_untouched() {
        // unwrap-free and loom-shim only bind the pooled scheduler
        let src = "use std::sync::Arc;\nlet g = m.lock().unwrap();\n";
        assert!(lint_file("pipeline/driver.rs", src).is_empty());
        assert!(lint_file("serve/threaded.rs", src).is_empty());
    }

    // -- the shipped tree must be clean --------------------------------

    #[test]
    fn real_tree_passes_all_lints() {
        let src_root = repo_root().join("rust").join("src");
        let (n, hits) = lint_tree(&src_root).expect("walk rust/src");
        assert!(n > 20, "suspiciously few files scanned: {n}");
        assert!(
            hits.is_empty(),
            "tree has lint violations:\n{}",
            hits.iter()
                .map(|v| v.render())
                .collect::<Vec<_>>()
                .join("\n")
        );
    }
}
