#!/usr/bin/env bash
# CI / local verification: formatting, lints, tests, docs, scenario smoke.
# Usage: scripts/verify.sh [--deep]
#   --deep  additionally run the concurrency-correctness lanes: loom
#           model checking, Miri (pure modules), and ThreadSanitizer.
#           Miri/TSan need a nightly toolchain (miri + rust-src
#           components) and are skipped with a notice if unavailable;
#           loom runs on stable and is never skipped.
set -euo pipefail
cd "$(dirname "$0")/.."

DEEP=0
for arg in "$@"; do
    case "$arg" in
        --deep) DEEP=1 ;;
        *) echo "usage: scripts/verify.sh [--deep]" >&2; exit 2 ;;
    esac
done

echo "== cargo fmt --check =="
if cargo fmt --version >/dev/null 2>&1; then
    cargo fmt --all -- --check
else
    echo "(rustfmt unavailable; skipping)"
fi

echo "== cargo clippy -D warnings =="
if cargo clippy --version >/dev/null 2>&1; then
    cargo clippy --workspace --all-targets -- -D warnings
else
    echo "(clippy unavailable; skipping)"
fi

echo "== cargo xtask lint (invariant linter) =="
cargo xtask lint

echo "== cargo test =="
cargo test -q

echo "== cargo doc --no-deps (warnings denied) =="
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

echo "== scenario smoke: coach run on every scenarios/*.toml (DES) =="
cargo build --release --quiet
for f in scenarios/*.toml; do
    echo "-- $f"
    ./target/release/coach run "$f" --n 80
done

echo "== replan bench smoke: tiny-n coach bench-fig5 emits BENCH_fig5_replan.json =="
BENCH_DIR="$(mktemp -d)"
COACH_BENCH_DIR="$BENCH_DIR" ./target/release/coach bench-fig5 --n 40
test -s "$BENCH_DIR/BENCH_fig5_replan.json" \
    || { echo "BENCH_fig5_replan.json missing"; exit 1; }
rm -rf "$BENCH_DIR"

echo "== DES scale smoke: tiny-n coach bench-des-scale emits BENCH_des_scale.json =="
BENCH_DIR="$(mktemp -d)"
COACH_BENCH_DIR="$BENCH_DIR" ./target/release/coach bench-des-scale \
    --streams 64 --tasks 5 --shards 2
test -s "$BENCH_DIR/BENCH_des_scale.json" \
    || { echo "BENCH_des_scale.json missing"; exit 1; }
grep -q events_per_sec "$BENCH_DIR/BENCH_des_scale.json" \
    || { echo "BENCH_des_scale.json lacks events_per_sec"; exit 1; }
rm -rf "$BENCH_DIR"

echo "== cloud batch smoke: tiny-n coach bench-cloud-batch emits BENCH_cloud_batch.json =="
BENCH_DIR="$(mktemp -d)"
COACH_BENCH_DIR="$BENCH_DIR" ./target/release/coach bench-cloud-batch \
    --streams 8,16 --tasks 5
test -s "$BENCH_DIR/BENCH_cloud_batch.json" \
    || { echo "BENCH_cloud_batch.json missing"; exit 1; }
grep -q throughput "$BENCH_DIR/BENCH_cloud_batch.json" \
    || { echo "BENCH_cloud_batch.json lacks throughput"; exit 1; }
grep -q batch_occupancy "$BENCH_DIR/BENCH_cloud_batch.json" \
    || { echo "BENCH_cloud_batch.json lacks batch_occupancy"; exit 1; }
rm -rf "$BENCH_DIR"

echo "== serve scale smoke: tiny-n coach bench-serve-scale emits BENCH_serve_scale.json =="
BENCH_DIR="$(mktemp -d)"
COACH_BENCH_DIR="$BENCH_DIR" ./target/release/coach bench-serve-scale \
    --streams 4,8 --tasks 3
test -s "$BENCH_DIR/BENCH_serve_scale.json" \
    || { echo "BENCH_serve_scale.json missing"; exit 1; }
grep -q streams "$BENCH_DIR/BENCH_serve_scale.json" \
    || { echo "BENCH_serve_scale.json lacks streams"; exit 1; }
grep -q throughput "$BENCH_DIR/BENCH_serve_scale.json" \
    || { echo "BENCH_serve_scale.json lacks throughput"; exit 1; }
grep -q steals "$BENCH_DIR/BENCH_serve_scale.json" \
    || { echo "BENCH_serve_scale.json lacks steals"; exit 1; }
grep -q worker_busy_frac "$BENCH_DIR/BENCH_serve_scale.json" \
    || { echo "BENCH_serve_scale.json lacks worker_busy_frac"; exit 1; }
rm -rf "$BENCH_DIR"

echo "== pooled serve-sim smoke: wide fleet on the worker-pool engine =="
./target/release/coach serve-sim --streams 1024 --n 5 --runtime pooled
echo "== pinned serve-sim smoke: same fleet, stealing disabled =="
./target/release/coach serve-sim --streams 1024 --n 5 --runtime pooled \
    --steal false

if [ "$DEEP" = 1 ]; then
    echo "== [deep] loom: checker self-tests + scheduler models =="
    cargo test --release -p loom
    RUSTFLAGS="--cfg loom" cargo test --release -p coach --test loom_pool

    echo "== [deep] miri: UB check over the pure modules =="
    if rustup run nightly cargo miri --version >/dev/null 2>&1; then
        rustup run nightly cargo miri test -p coach --lib -- \
            evq:: slab:: timer:: quant:: --skip prop_
    else
        echo "(nightly miri unavailable; skipping — CI 'miri' job covers this)"
    fi

    echo "== [deep] tsan: race check over the concurrent suites =="
    if rustup run nightly rustc --version >/dev/null 2>&1 \
        && [ -d "$(rustup run nightly rustc --print sysroot)/lib/rustlib/src/rust/library" ]; then
        RUSTFLAGS="-Zsanitizer=thread" \
        rustup run nightly cargo test -Zbuild-std \
            --target x86_64-unknown-linux-gnu \
            -p coach --test serve_sched_e2e --test determinism
    else
        echo "(nightly rust-src unavailable; skipping — CI 'tsan' job covers this)"
    fi
fi

echo "verify OK"
