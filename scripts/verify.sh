#!/usr/bin/env bash
# CI / local verification: formatting, lints, tests.
# Usage: scripts/verify.sh
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== cargo fmt --check =="
if cargo fmt --version >/dev/null 2>&1; then
    cargo fmt --all -- --check
else
    echo "(rustfmt unavailable; skipping)"
fi

echo "== cargo clippy -D warnings =="
if cargo clippy --version >/dev/null 2>&1; then
    cargo clippy --workspace --all-targets -- -D warnings
else
    echo "(clippy unavailable; skipping)"
fi

echo "== cargo test =="
cargo test -q

echo "verify OK"
