fn main() {}
