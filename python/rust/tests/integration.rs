#[test] fn placeholder() {}
