pub mod runtime;
