pub fn placeholder() {}
