fn main() { println!("coach"); }
