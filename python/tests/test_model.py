"""L2 model sanity: topology, shapes, determinism, quant-at-cut dataflow."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M


@pytest.fixture(scope="module")
def models():
    return {name: build() for name, build in M.MODELS.items()}


@pytest.fixture(scope="module")
def patterns():
    return M.class_patterns()


def test_model_registry(models):
    assert set(models) == {"vgg_mini", "resnet_mini"}
    assert models["vgg_mini"].topology == "chain"
    assert models["resnet_mini"].topology == "dag"


@pytest.mark.parametrize("name", list(M.MODELS))
def test_block_shapes_chain_up(models, name):
    m = models[name]
    assert m.blocks[0].in_shape == M.INPUT_SHAPE
    for a, b in zip(m.blocks, m.blocks[1:]):
        assert a.out_shape == b.in_shape
    assert m.blocks[-1].out_shape == (M.N_CLASSES,)


@pytest.mark.parametrize("name", list(M.MODELS))
def test_forward_runs_and_matches_blockwise(models, name):
    m = models[name]
    x = jax.random.normal(jax.random.PRNGKey(0), M.INPUT_SHAPE)
    logits = m.forward(x)
    assert logits.shape == (M.N_CLASSES,)
    # block-by-block execution (what rust does) == whole forward
    y = x
    for blk in m.blocks:
        assert y.shape == blk.in_shape
        y = blk.fn(y)
    np.testing.assert_allclose(y, logits, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("name", list(M.MODELS))
def test_forward_deterministic(name):
    m1, m2 = M.MODELS[name](), M.MODELS[name]()
    x = jax.random.normal(jax.random.PRNGKey(1), M.INPUT_SHAPE)
    np.testing.assert_allclose(m1.forward(x), m2.forward(x), atol=0)


@pytest.mark.parametrize("name", list(M.MODELS))
def test_quant_at_cut_high_bits_preserves_argmax(models, name):
    m = models[name]
    x = jax.random.normal(jax.random.PRNGKey(2), M.INPUT_SHAPE)
    base = int(jnp.argmax(m.forward(x)))
    for cut in range(len(m.blocks) - 1):
        q = m.forward_quant_at(x, cut, float(2**8 - 1))
        assert int(jnp.argmax(q)) == base, f"cut={cut}"


def test_quant_low_bits_perturbs_more(models):
    m = models["vgg_mini"]
    x = jax.random.normal(jax.random.PRNGKey(3), M.INPUT_SHAPE)
    base = m.forward(x)
    e2 = float(jnp.mean((m.forward_quant_at(x, 0, 3.0) - base) ** 2))
    e8 = float(jnp.mean((m.forward_quant_at(x, 0, 255.0) - base) ** 2))
    assert e2 > e8


def test_class_patterns_cluster_features(models, patterns):
    """Fig. 1 observation: GAP features of same-class samples are closer
    to their class center than to other centers (on average)."""
    from compile.kernels import ref

    m = models["resnet_mini"]
    device_blocks = m.blocks[:-1]

    def feat(x):
        y = x
        for blk in device_blocks:
            y = blk.fn(y)
        return ref.gap(y)

    rng = jax.random.PRNGKey(4)
    n_cls = 6
    centers, samples = [], []
    for c in range(n_cls):
        keys = jax.random.split(jax.random.fold_in(rng, c), 4)
        fs = jnp.stack([feat(M.sample(patterns, c, k)) for k in keys])
        centers.append(fs.mean(0))
        samples.append(fs)
    centers = jnp.stack(centers)

    def cos(a, b):
        return float(jnp.dot(a, b) /
                     (jnp.linalg.norm(a) * jnp.linalg.norm(b) + 1e-9))

    correct = 0
    total = 0
    for c in range(n_cls):
        for f in samples[c]:
            sims = [cos(f, centers[j]) for j in range(n_cls)]
            correct += int(np.argmax(sims) == c)
            total += 1
    assert correct / total > 0.8
