"""L1 correctness: Pallas kernels vs pure-jnp oracles (ref.py).

hypothesis sweeps shapes and value ranges; assert_allclose against the
oracle is the core Layer-1 signal (interpret=True path — the same
lowering the shipped artifacts use).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import dense, gap, ref, uaq

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def _arr(rng, shape, lo=-4.0, hi=4.0):
    return jnp.asarray(
        rng.uniform(lo, hi, size=shape).astype(np.float32))


# --------------------------------------------------------------------------
# UAQ round trip
# --------------------------------------------------------------------------

@given(
    n=st.integers(1, 5000),
    bits=st.integers(2, 8),
    seed=st.integers(0, 2**16),
)
def test_uaq_matches_ref_flat(n, bits, seed):
    rng = np.random.default_rng(seed)
    x = _arr(rng, (n,))
    levels = float(2**bits - 1)
    got = uaq.uaq_roundtrip(x, levels)
    want = ref.uaq_roundtrip(x, levels)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


@given(
    c=st.integers(1, 16),
    h=st.integers(1, 12),
    w=st.integers(1, 12),
    bits=st.integers(2, 8),
    seed=st.integers(0, 2**16),
)
def test_uaq_matches_ref_3d(c, h, w, bits, seed):
    rng = np.random.default_rng(seed)
    x = _arr(rng, (c, h, w))
    levels = float(2**bits - 1)
    got = uaq.uaq_roundtrip(x, levels)
    want = ref.uaq_roundtrip(x, levels)
    assert got.shape == x.shape
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


@given(bits=st.integers(2, 8), seed=st.integers(0, 2**16))
def test_uaq_error_bounded_by_half_step(bits, seed):
    """|x - roundtrip(x)| <= scale/2 everywhere — the UAQ invariant."""
    rng = np.random.default_rng(seed)
    x = _arr(rng, (777,))
    levels = 2**bits - 1
    scale = (float(x.max()) - float(x.min())) / levels
    got = uaq.uaq_roundtrip(x, float(levels))
    assert float(jnp.max(jnp.abs(got - x))) <= scale / 2 + 1e-6


def test_uaq_constant_tensor_degenerate():
    x = jnp.full((64,), 3.25, jnp.float32)
    got = uaq.uaq_roundtrip(x, 255.0)
    np.testing.assert_allclose(got, x, atol=1e-5)


def test_uaq_monotone_error_in_bits():
    rng = np.random.default_rng(0)
    x = _arr(rng, (4096,))
    errs = [
        float(jnp.mean((uaq.uaq_roundtrip(x, float(2**b - 1)) - x) ** 2))
        for b in range(2, 9)
    ]
    assert all(a >= b for a, b in zip(errs, errs[1:]))


def test_minmax_matches_numpy():
    rng = np.random.default_rng(1)
    x = _arr(rng, (3000,))
    mn, mx = uaq.minmax(x)
    assert float(mn) == pytest.approx(float(x.min()))
    assert float(mx) == pytest.approx(float(x.max()))


# --------------------------------------------------------------------------
# GAP
# --------------------------------------------------------------------------

@given(
    c=st.integers(1, 40),
    h=st.integers(1, 16),
    w=st.integers(1, 16),
    seed=st.integers(0, 2**16),
)
def test_gap_matches_ref(c, h, w, seed):
    rng = np.random.default_rng(seed)
    x = _arr(rng, (c, h, w))
    got = gap.gap(x)
    assert got.shape == (c,)
    np.testing.assert_allclose(got, ref.gap(x), rtol=1e-5, atol=1e-6)


def test_gap_constant_channels():
    x = jnp.stack([jnp.full((8, 8), float(i)) for i in range(5)])
    np.testing.assert_allclose(gap.gap(x), jnp.arange(5.0), atol=1e-6)


# --------------------------------------------------------------------------
# fused dense
# --------------------------------------------------------------------------

@given(
    m=st.integers(1, 20),
    k=st.integers(1, 96),
    n=st.integers(1, 200),
    seed=st.integers(0, 2**16),
)
def test_dense_relu_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x = _arr(rng, (m, k), -1, 1)
    w = _arr(rng, (k, n), -1, 1)
    b = _arr(rng, (n,), -1, 1)
    got = dense.dense_relu(x, w, b)
    want = ref.dense_relu(x, w, b)
    assert got.shape == (m, n)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_dense_relu_nonnegative():
    rng = np.random.default_rng(3)
    x, w, b = _arr(rng, (4, 8)), _arr(rng, (8, 16)), _arr(rng, (16,))
    assert float(jnp.min(dense.dense_relu(x, w, b))) >= 0.0
