use coach::runtime::{default_artifact_dir, Engine, Manifest, ModelRuntime, Tensor};
fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load(&default_artifact_dir())?;
    let engine = Engine::new(&manifest)?;
    let patterns = manifest.read_f32(&manifest.patterns.file)?;
    println!("patterns[0..5]={:?}", &patterns[0..5]);
    let isz: usize = manifest.input_shape.iter().product();
    let x = Tensor::new(manifest.input_shape.clone(), patterns[0..isz].to_vec())?;
    let rt = ModelRuntime::new(&engine, &manifest, "vgg_mini")?;
    let b0 = rt.run_blocks(0,1,&x)?;
    println!("b0 shape={:?} first5={:?} sum={}", b0.shape, &b0.data[0..5], b0.data.iter().sum::<f32>());
    let lg = rt.run_blocks(0, rt.model.blocks.len(), &x)?;
    println!("logits={:?}", lg.data);
    Ok(())
}
