"""Layer-2 JAX model definitions (build-time only).

Two small-but-real CNNs with the topology *shapes* the paper evaluates —
a chain model (``vgg_mini``, VGG16-style 3x3 conv stacks) and a DAG model
(``resnet_mini``, residual blocks with skip branches) — on 32x32x3 inputs
with ``N_CLASSES`` outputs. Weights are deterministic (fixed PRNG seed)
and baked into the lowered HLO as constants, so the rust runtime needs no
weight loading.

Each model is expressed as an ordered list of BLOCKS (activation ->
activation functions). ``aot.py`` lowers every block to its own HLO-text
artifact; a partition cut after block *k* means the end device executes
blocks ``0..=k`` and the cloud executes ``k+1..``, with the UAQ kernel
applied to the cut activation. This gives the rust coordinator every cut
point at runtime from a linear number of artifacts.

Classifier heads call the Layer-1 Pallas kernels (``dense.dense_relu``,
``gap.gap``) so they lower into the same HLO as the surrounding jnp ops.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .kernels import dense as kdense
from .kernels import gap as kgap

N_CLASSES = 20
INPUT_SHAPE = (3, 32, 32)
SEED = 20240710
_PROTO_PER_CLASS = 3  # calibration samples per class for the prototype head


# --------------------------------------------------------------------------
# primitives
# --------------------------------------------------------------------------

def conv2d(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
           stride: int = 1) -> jnp.ndarray:
    """3x3 'SAME' conv over a single sample ``(C, H, W)``, NCHW/OIHW."""
    y = lax.conv_general_dilated(
        x[None],
        w,
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )[0]
    return y + b[:, None, None]


def relu(x: jnp.ndarray) -> jnp.ndarray:
    return jnp.maximum(x, 0.0)


def maxpool2(x: jnp.ndarray) -> jnp.ndarray:
    """2x2/2 max pool over ``(C, H, W)``."""
    return lax.reduce_window(
        x, -jnp.inf, lax.max, (1, 2, 2), (1, 2, 2), "VALID"
    )


def _he(key, shape):
    fan_in = 1
    for d in shape[1:]:
        fan_in *= d
    return jax.random.normal(key, shape, jnp.float32) * jnp.sqrt(2.0 / fan_in)


class _Params:
    """Deterministic parameter factory (split-per-call on a fixed seed)."""

    def __init__(self, seed: int):
        self._key = jax.random.PRNGKey(seed)

    def conv(self, c_out: int, c_in: int, k: int = 3):
        self._key, sub = jax.random.split(self._key)
        w = _he(sub, (c_out, c_in, k, k))
        b = jnp.zeros((c_out,), jnp.float32)
        return w, b

    def dense(self, d_in: int, d_out: int):
        self._key, sub = jax.random.split(self._key)
        w = _he(sub, (d_in, d_out)).reshape(d_in, d_out)
        b = jnp.zeros((d_out,), jnp.float32)
        return w, b


# --------------------------------------------------------------------------
# model/block definitions
# --------------------------------------------------------------------------

@dataclasses.dataclass
class BlockDef:
    """One pipeline-partitionable unit: ``fn`` maps the block's input
    activation to its output activation. ``kind`` tags the topology role
    ('chain' plain block, 'residual' DAG block with a skip branch,
    'head' classifier)."""

    name: str
    fn: Callable[[jnp.ndarray], jnp.ndarray]
    in_shape: Tuple[int, ...]
    out_shape: Tuple[int, ...]
    kind: str = "chain"


@dataclasses.dataclass
class ModelDef:
    name: str
    topology: str  # 'chain' | 'dag'
    blocks: List[BlockDef]

    def forward(self, x: jnp.ndarray) -> jnp.ndarray:
        for b in self.blocks:
            x = b.fn(x)
        return x

    def forward_quant_at(self, x: jnp.ndarray, cut: int,
                         levels: float) -> jnp.ndarray:
        """fp32 up to (and incl.) block ``cut``, UAQ round trip on the
        cut activation, fp32 for the rest — the collaborative-inference
        dataflow used to build the accuracy (fidelity) table."""
        from .kernels import ref

        for b in self.blocks[: cut + 1]:
            x = b.fn(x)
        x = ref.uaq_roundtrip(x, levels)
        for b in self.blocks[cut + 1:]:
            x = b.fn(x)
        return x


def _shape_after(fn, in_shape):
    out = jax.eval_shape(fn, jax.ShapeDtypeStruct(in_shape, jnp.float32))
    return tuple(out.shape)


def _normalize(f: jnp.ndarray) -> jnp.ndarray:
    """Feature standardization before the classifier (plays the role
    batch-norm statistics play in a trained network: kills the large
    data-independent mean component of random-weight features so the
    data-dependent part drives the logits)."""
    return (f - jnp.mean(f)) / (jnp.std(f) + 1e-5)


def _prototype_head(feature_fn, feat_dim: int, n_classes: int, seed: int):
    """Calibrated prototype classifier (one-pass linear probe).

    Class weights are the normalized per-class mean features over a small
    deterministic calibration set — a nearest-class-center classifier.
    This gives the random-weight backbones *trained-like* behaviour:
    predictions spread over all classes and margins sit at realistic
    scales, so quantization at the cut measurably perturbs accuracy
    (the regime the paper's Eq. 1 constraint lives in).
    """
    pats = class_patterns(n_classes)
    protos = []
    for c in range(n_classes):
        keys = jax.random.split(
            jax.random.fold_in(jax.random.PRNGKey(seed), c),
            _PROTO_PER_CLASS,
        )
        fs = jnp.stack([
            _normalize(feature_fn(sample(pats, c, k))) for k in keys
        ])
        mu = fs.mean(0)
        protos.append(mu / (jnp.linalg.norm(mu) + 1e-9))
    w = jnp.stack(protos, axis=1)  # (feat_dim, n_classes)
    assert w.shape == (feat_dim, n_classes)
    return w


def _chain_block(name, fns, in_shape, kind="chain"):
    def fn(x, _fns=tuple(fns)):
        for f in _fns:
            x = f(x)
        return x

    return BlockDef(name, fn, in_shape, _shape_after(fn, in_shape), kind)


def build_vgg_mini(n_classes: int = N_CLASSES) -> ModelDef:
    """Chain topology: three conv stages (2x conv3 + pool) + 2-layer head.

    Mirrors VGG16's profile: compute mass concentrated early (big spatial
    planes), activation size shrinking monotonically — the regime where
    Neurosurgeon-style single-cut partitioning already works well.
    """
    p = _Params(SEED)
    blocks: List[BlockDef] = []
    shape = INPUT_SHAPE

    widths = [(3, 32), (32, 64), (64, 128)]
    for i, (c_in, c_out) in enumerate(widths):
        w1, b1 = p.conv(c_out, c_in)
        w2, b2 = p.conv(c_out, c_out)
        blk = _chain_block(
            f"stage{i}",
            [
                lambda x, w=w1, b=b1: relu(conv2d(x, w, b)),
                lambda x, w=w2, b=b2: relu(conv2d(x, w, b)),
                maxpool2,
            ],
            shape,
        )
        blocks.append(blk)
        shape = blk.out_shape

    flat_dim = shape[0] * shape[1] * shape[2]
    wf, bf = p.dense(flat_dim, 128)

    def head1(x, w=wf, b=bf):
        return kdense.dense_relu(x.reshape(1, -1), w, b)[0]

    blocks.append(BlockDef("fc_relu", head1, shape, (128,), "head"))

    def feature_fn(x, _blocks=tuple(b.fn for b in blocks)):
        for f in _blocks:
            x = f(x)
        return x

    wo = _prototype_head(feature_fn, 128, n_classes, SEED + 3)

    def head2(x, w=wo):
        return _normalize(x) @ w * 10.0

    blocks.append(BlockDef("logits", head2, (128,), (n_classes,), "head"))
    return ModelDef("vgg_mini", "chain", blocks)


def _residual_block(p: _Params, name, c_in, c_out, stride, in_shape):
    w1, b1 = p.conv(c_out, c_in)
    w2, b2 = p.conv(c_out, c_out)
    if stride != 1 or c_in != c_out:
        ws, bs = p.conv(c_out, c_in, k=1)
    else:
        ws = bs = None

    def fn(x):
        y = relu(conv2d(x, w1, b1, stride=stride))
        y = conv2d(y, w2, b2)
        skip = x if ws is None else conv2d(x, ws, bs, stride=stride)
        return relu(y + skip)

    return BlockDef(name, fn, in_shape, _shape_after(fn, in_shape),
                    "residual")


def build_resnet_mini(n_classes: int = N_CLASSES) -> ModelDef:
    """DAG topology: stem + 5 residual blocks (skip branches) + GAP head.

    Mirrors ResNet101's profile: a long tail of medium-cost blocks with
    parallel (skip) data flows — the regime where the paper's virtual-
    block divide-and-conquer matters.
    """
    p = _Params(SEED + 1)
    blocks: List[BlockDef] = []
    shape = INPUT_SHAPE

    w0, b0 = p.conv(32, 3)
    stem = _chain_block("stem", [lambda x, w=w0, b=b0: relu(conv2d(x, w, b))],
                        shape)
    blocks.append(stem)
    shape = stem.out_shape

    spec = [(32, 32, 1), (32, 64, 2), (64, 64, 1), (64, 128, 2),
            (128, 128, 1)]
    for i, (ci, co, st) in enumerate(spec):
        blk = _residual_block(p, f"res{i}", ci, co, st, shape)
        blocks.append(blk)
        shape = blk.out_shape

    def feature_fn(x, _blocks=tuple(b.fn for b in blocks)):
        for f in _blocks:
            x = f(x)
        return kgap.gap(x)

    wo = _prototype_head(feature_fn, shape[0], n_classes, SEED + 4)

    def head(x, w=wo):
        f = _normalize(kgap.gap(x))
        return f @ w * 10.0

    blocks.append(BlockDef("gap_logits", head, shape, (n_classes,), "head"))
    return ModelDef("resnet_mini", "dag", blocks)


MODELS = {
    "vgg_mini": build_vgg_mini,
    "resnet_mini": build_resnet_mini,
}


# --------------------------------------------------------------------------
# synthetic class-conditional data (shared with the rust workload
# generator via artifacts/class_patterns.f32 — see aot.py)
# --------------------------------------------------------------------------

def class_patterns(n_classes: int = N_CLASSES,
                   seed: int = SEED + 7) -> jnp.ndarray:
    """Per-class mean images, ``(n_classes, C, H, W)``. A sample of class
    ``j`` is ``patterns[j] + sigma * noise`` — class-conditional Gaussians
    whose GAP features cluster by label (the paper's Fig. 1 observation)."""
    key = jax.random.PRNGKey(seed)
    return jax.random.normal(key, (n_classes,) + INPUT_SHAPE, jnp.float32)


def sample(patterns: jnp.ndarray, label: int, key,
           sigma: float = 0.35) -> jnp.ndarray:
    noise = jax.random.normal(key, INPUT_SHAPE, jnp.float32)
    return patterns[label] + sigma * noise
