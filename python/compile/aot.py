"""AOT lowering: JAX (L2) + Pallas (L1) -> HLO-text artifacts for rust (L3).

Runs ONCE at build time (`make artifacts`); python is never on the
request path. Emits into ``artifacts/``:

- ``{model}_b{i}.hlo.txt``   — one executable per model block (any cut
                               point is then runnable from rust),
- ``uaq_{N}.hlo.txt``        — UAQ round-trip for each distinct cut
                               activation size N (levels is a runtime
                               input, so one artifact serves 2..8 bit),
- ``gap_{C}x{H}x{W}.hlo.txt``— GAP feature extractor per cut shape,
- ``manifest.json``          — the full artifact/shape index rust loads,
- ``acc_table.json``         — measured precision->fidelity curves per
                               (model, cut); the offline dichotomous
                               search (paper Eq. 1) consumes these,
- ``class_patterns.f32`` / ``calib_inputs.f32`` + labels — synthetic
  class-conditional data shared with the rust workload generator and
  semantic-cache warmup.

Interchange is HLO *text*, not serialized protos: jax >= 0.5 emits
64-bit instruction ids that xla_extension 0.5.1 rejects; the text parser
reassigns ids (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels import gap as kgap
from .kernels import uaq as kuaq

BITS_RANGE = range(2, 9)
N_ACC_SAMPLES = 100  # fidelity-measurement samples per (model, cut, bits)
N_CALIB_PER_CLASS = 3
SIGMA = 0.35


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (return_tuple=True; rust
    unwraps with to_tuple1)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants: the default printer elides big weight
    # literals as `constant({...})`, which the 0.5.1 text parser then
    # silently reads back as ZEROS — the weights must be in the text.
    return comp.as_hlo_text(print_large_constants=True)


def lower_fn(fn, example_args, path: pathlib.Path) -> None:
    lowered = jax.jit(fn).lower(*example_args)
    path.write_text(to_hlo_text(lowered))


def _spec(shape):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.float32)


def lower_model_blocks(m: M.ModelDef, outdir: pathlib.Path):
    entries = []
    for i, blk in enumerate(m.blocks):
        fname = f"{m.name}_b{i}.hlo.txt"
        lower_fn(lambda x, f=blk.fn: (f(x),), [_spec(blk.in_shape)],
                 outdir / fname)
        entries.append({
            "name": blk.name,
            "kind": blk.kind,
            "artifact": fname,
            "in_shape": list(blk.in_shape),
            "out_shape": list(blk.out_shape),
        })
        print(f"  lowered {m.name} block {i} ({blk.name}) "
              f"{blk.in_shape} -> {blk.out_shape}")
    return entries


def lower_uaq(sizes, outdir: pathlib.Path):
    out = {}
    for n in sorted(sizes):
        fname = f"uaq_{n}.hlo.txt"
        lower_fn(
            lambda x, lv: (kuaq.uaq_roundtrip(x, lv),),
            [_spec((n,)), _spec((1,))],
            outdir / fname,
        )
        out[str(n)] = fname
        print(f"  lowered uaq_{n}")
    return out


def lower_gap(shapes, outdir: pathlib.Path):
    out = {}
    for shp in sorted(shapes):
        key = "x".join(map(str, shp))
        fname = f"gap_{key}.hlo.txt"
        lower_fn(lambda x: (kgap.gap(x),), [_spec(shp)], outdir / fname)
        out[key] = fname
        print(f"  lowered gap_{key}")
    return out


def measure_acc_table(models, patterns, rng):
    """Top-1 fidelity (agreement with the fp32 model) per (model, cut
    position, bits). This is the measured monotone curve the offline
    dichotomous search walks to satisfy |Acc - Acc(Q)| <= eps."""
    table = {}
    keys = jax.random.split(jax.random.PRNGKey(99), N_ACC_SAMPLES)
    xs = []
    for i, k in enumerate(keys):
        a, b = rng.integers(0, M.N_CLASSES, 2)
        if i % 2 == 0:
            xs.append(M.sample(patterns, int(a), k, SIGMA))
        else:
            # boundary-stressed: between-class mixture. Real calibration
            # sets contain hard near-boundary examples; these are what
            # make the precision->accuracy curve bind (see DESIGN.md §3).
            mix = 0.7 * patterns[int(a)] + 0.3 * patterns[int(b)]
            noise = jax.random.normal(k, M.INPUT_SHAPE, jnp.float32)
            xs.append(mix + SIGMA * noise)
    xs = jnp.stack(xs)
    for name, m in models.items():
        fwd = jax.jit(jax.vmap(m.forward))
        base = np.argmax(np.asarray(fwd(xs)), axis=1)
        per_cut = {}
        # cut after block i (last block excluded: nothing left to offload)
        for cut in range(len(m.blocks) - 1):
            fq = jax.jit(
                jax.vmap(m.forward_quant_at, in_axes=(0, None, None)),
                static_argnums=(1,),
            )
            per_bits = {}
            for bits in BITS_RANGE:
                levels = float(2 ** bits - 1)
                pred = np.argmax(np.asarray(fq(xs, cut, levels)), axis=1)
                per_bits[str(bits)] = float((pred == base).mean())
            per_cut[str(cut)] = per_bits
            print(f"  acc {name} cut={cut}: "
                  + " ".join(f"{b}:{v:.2f}" for b, v in per_bits.items()))
        table[name] = per_cut
    return table


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--skip-acc", action="store_true",
                    help="skip the fidelity measurement (fast dev cycle)")
    args = ap.parse_args()
    outdir = pathlib.Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)

    models = {name: build() for name, build in M.MODELS.items()}

    manifest = {
        "n_classes": M.N_CLASSES,
        "input_shape": list(M.INPUT_SHAPE),
        "models": {},
    }

    uaq_sizes, gap_shapes = set(), set()
    for name, m in models.items():
        print(f"lowering {name} ({m.topology}, {len(m.blocks)} blocks)")
        entries = lower_model_blocks(m, outdir)
        manifest["models"][name] = {
            "topology": m.topology,
            "blocks": entries,
        }
        for blk in m.blocks[:-1]:  # every possible cut activation
            shp = blk.out_shape
            uaq_sizes.add(int(np.prod(shp)))
            if len(shp) == 3:
                gap_shapes.add(tuple(shp))

    manifest["uaq"] = lower_uaq(uaq_sizes, outdir)
    manifest["gap"] = lower_gap(gap_shapes, outdir)

    # --- shared synthetic data -------------------------------------------
    patterns = M.class_patterns()
    np.asarray(patterns, np.float32).tofile(outdir / "class_patterns.f32")
    manifest["patterns"] = {
        "file": "class_patterns.f32",
        "shape": [M.N_CLASSES] + list(M.INPUT_SHAPE),
        "sigma": SIGMA,
    }

    rng = np.random.default_rng(M.SEED)
    calib_labels = [c for c in range(M.N_CLASSES)
                    for _ in range(N_CALIB_PER_CLASS)]
    keys = jax.random.split(jax.random.PRNGKey(7), len(calib_labels))
    calib = jnp.stack([
        M.sample(patterns, l, k, SIGMA) for l, k in zip(calib_labels, keys)
    ])
    np.asarray(calib, np.float32).tofile(outdir / "calib_inputs.f32")
    manifest["calib"] = {
        "inputs": "calib_inputs.f32",
        "labels": calib_labels,
        "count": len(calib_labels),
    }

    # --- measured precision -> fidelity curves ---------------------------
    if args.skip_acc:
        acc = {}
    else:
        acc = measure_acc_table(models, patterns, rng)
    (outdir / "acc_table.json").write_text(json.dumps(acc, indent=1))
    manifest["acc_table"] = "acc_table.json"

    (outdir / "manifest.json").write_text(json.dumps(manifest, indent=1))
    print(f"wrote {outdir}/manifest.json")


if __name__ == "__main__":
    main()
