"""Pallas fused dense (matmul + bias + ReLU) kernel — Layer 1.

The classifier-head hot loop of both models: ``relu(x @ w + b)``. On TPU
this is the MXU workload — tiles are sized in (8, 128) multiples so the
systolic array runs full, the K dimension stays VMEM-resident per block,
and bias+ReLU fuse into the same VMEM pass as the matmul epilogue
(no extra HBM round trip for the activation).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-aligned output tile: 8 sublanes x 128 lanes.
TILE_M = 8
TILE_N = 128


def _dense_relu_kernel(x_ref, w_ref, b_ref, o_ref):
    acc = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] = jnp.maximum(acc + b_ref[...][None, :], 0.0)


def _pad_to(x: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    n = x.shape[axis]
    padded = ((n + mult - 1) // mult) * mult
    if padded == n:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, padded - n)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("tile_m", "tile_n"))
def dense_relu(
    x: jnp.ndarray,
    w: jnp.ndarray,
    b: jnp.ndarray,
    tile_m: int = TILE_M,
    tile_n: int = TILE_N,
) -> jnp.ndarray:
    """``relu(x @ w + b)`` with MXU-tiled Pallas. Matches ``ref.dense_relu``.

    ``x: (M, K)``, ``w: (K, N)``, ``b: (N,)``. M and N are zero-padded to
    tile multiples and sliced back; K rides whole in VMEM (our heads have
    K <= 2048 -> x-tile 8x2048 f32 = 64 KiB, w-tile 2048x128 = 1 MiB,
    within budget with double buffering).
    """
    m, k = x.shape
    _, n = w.shape
    xp = _pad_to(x, 0, tile_m)
    wp = _pad_to(w, 1, tile_n)
    bp = _pad_to(b, 0, tile_n)
    gm, gn = xp.shape[0] // tile_m, wp.shape[1] // tile_n
    out = pl.pallas_call(
        _dense_relu_kernel,
        grid=(gm, gn),
        in_specs=[
            pl.BlockSpec((tile_m, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, tile_n), lambda i, j: (0, j)),
            pl.BlockSpec((tile_n,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((tile_m, tile_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((xp.shape[0], wp.shape[1]), x.dtype),
        interpret=True,
    )(xp, wp, bp)
    return out[:m, :n]
