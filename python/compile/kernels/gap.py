"""Pallas Global Average Pooling kernel — Layer 1.

GAP reduces the cut activation ``(C, H, W)`` to the task feature ``F`` of
shape ``(C,)`` that the online component's semantic cache consumes (paper
§III-C). It runs on the DEVICE side for every task, right before the
early-exit / quantization-adjustment decision, so it sits on the hot path.

TPU mapping: channel-major tiling — each grid step holds a ``(TC, H, W)``
block in VMEM and reduces its spatial plane on the VPU, writing ``TC``
feature lanes. One HBM pass, no re-reads.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Channels per VMEM block. 8 channels x 32x32 f32 = 32 KiB — VPU-friendly
# sublane count, comfortably VMEM-resident alongside double buffers.
TILE_C = 8


def _gap_kernel(x_ref, o_ref):
    o_ref[...] = jnp.mean(x_ref[...], axis=(1, 2))


@functools.partial(jax.jit, static_argnames=("tile_c",))
def gap(x: jnp.ndarray, tile_c: int = TILE_C) -> jnp.ndarray:
    """``(C, H, W) -> (C,)`` mean over the spatial plane.

    Matches ``ref.gap``. C is zero-padded to a ``tile_c`` multiple for
    the grid; padding channels are sliced off (zeros never leak into the
    real channels' means because the reduction is per-channel).
    """
    c, h, w = x.shape
    padded_c = ((c + tile_c - 1) // tile_c) * tile_c
    if padded_c != c:
        x = jnp.concatenate(
            [x, jnp.zeros((padded_c - c, h, w), x.dtype)], axis=0
        )
    out = pl.pallas_call(
        _gap_kernel,
        grid=(padded_c // tile_c,),
        in_specs=[pl.BlockSpec((tile_c, h, w), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((tile_c,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((padded_c,), x.dtype),
        interpret=True,
    )(x)
    return out[:c]
