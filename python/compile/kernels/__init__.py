"""Layer-1 Pallas kernels (build-time only; lowered into L2 HLO).

- ``uaq``   -- Uniform Affine Quantization transmission round trip
- ``gap``   -- Global Average Pooling task-feature extractor
- ``dense`` -- fused matmul+bias+ReLU classifier head
- ``ref``   -- pure-jnp oracles for all of the above
"""

from . import dense, gap, ref, uaq  # noqa: F401
