"""Pure-jnp oracles for the Pallas kernels (L1 correctness ground truth).

Every kernel in this package has a reference implementation here written
with plain jax.numpy ops only. pytest (python/tests/) asserts
`assert_allclose(kernel(x), ref(x))` over hypothesis-generated shapes and
dtypes — this is the CORE correctness signal for Layer 1.
"""

from __future__ import annotations

import jax.numpy as jnp


def uaq_quantize(x: jnp.ndarray, levels):
    """Uniform Affine Quantization (UAQ, Krishnamoorthi 2018) forward.

    Maps ``x`` onto ``levels + 1`` uniformly spaced codes spanning
    ``[min(x), max(x)]``. Returns ``(codes, x_min, scale)`` where
    ``codes`` are float-typed integers in ``[0, levels]``.

    ``levels = 2**bits - 1`` is passed as data (not a static constant) so
    a single lowered artifact serves every precision 2..8-bit at runtime.
    """
    x_min = jnp.min(x)
    x_max = jnp.max(x)
    # Guard degenerate (constant) tensors: scale must stay positive.
    span = jnp.maximum(x_max - x_min, jnp.asarray(1e-8, x.dtype))
    scale = span / levels
    codes = jnp.clip(jnp.round((x - x_min) / scale), 0.0, levels)
    return codes, x_min, scale


def uaq_dequantize(codes: jnp.ndarray, x_min, scale):
    """Inverse of :func:`uaq_quantize`."""
    return codes * scale + x_min


def uaq_roundtrip(x: jnp.ndarray, levels):
    """Quantize-dequantize round trip — what the wire transmission does
    to the activation. This is the transmission hot-spot the Pallas
    kernel implements."""
    codes, x_min, scale = uaq_quantize(x, levels)
    return uaq_dequantize(codes, x_min, scale)


def gap(x: jnp.ndarray) -> jnp.ndarray:
    """Global Average Pooling: ``(C, H, W) -> (C,)`` (Lin et al. 2013).

    Produces the task feature ``F`` consumed by the online component's
    semantic cache (paper Eq. 7-10).
    """
    return jnp.mean(x, axis=(-2, -1))


def dense_relu(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Fused ``relu(x @ w + b)`` — the classifier-head hot loop."""
    return jnp.maximum(x @ w + b, 0.0)
