"""Pallas UAQ (Uniform Affine Quantization) transmission kernel — Layer 1.

This is the paper's transmission hot-spot: every intermediate activation
crossing the end->cloud cut is quantized to ``bits`` (2..8) before hitting
the wire and dequantized on the server (paper §III-B, Eq. 1-2; §III-C,
Eq. 11 picks ``bits`` online per task).

TPU mapping (DESIGN.md §Hardware-Adaptation): the activation is flattened
and tiled into VMEM-resident blocks; pass 1 is a sequential-grid min/max
reduction (the TPU grid is sequential, so accumulating into a single
(1,1)-block output is the idiomatic two-level reduction); pass 2 streams
each block HBM->VMEM once, applies the affine map on the VPU and streams
it back — two HBM passes total, no gather/scatter. ``levels = 2**bits-1``
rides along as a (1,)-shaped input so ONE lowered artifact serves every
precision at runtime (the rust coordinator feeds it per-task).

interpret=True everywhere: the CPU PJRT client cannot run Mosaic
custom-calls; numerics are validated through the interpret path against
`ref.py` and real-TPU efficiency is estimated in DESIGN.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# One VMEM block of the flattened activation. 2048 f32 = 8 KiB/block —
# small enough that x-in + out + double-buffering stay well under the
# ~16 MiB VMEM budget, large enough to keep the VPU lanes (8x128) full.
TILE = 2048


def _minmax_kernel(x_ref, min_ref, max_ref):
    """Sequential-grid min/max reduction; all grid steps share the
    (1,)-shaped output block (index_map pins it), so step i folds its
    tile extrema into the running result."""
    i = pl.program_id(0)
    tile_min = jnp.min(x_ref[...])
    tile_max = jnp.max(x_ref[...])

    @pl.when(i == 0)
    def _init():
        min_ref[0] = tile_min
        max_ref[0] = tile_max

    @pl.when(i > 0)
    def _fold():
        min_ref[0] = jnp.minimum(min_ref[0], tile_min)
        max_ref[0] = jnp.maximum(max_ref[0], tile_max)


def _roundtrip_kernel(x_ref, min_ref, scale_ref, levels_ref, o_ref):
    """Affine quantize-dequantize of one VMEM tile (pass 2)."""
    x_min = min_ref[0]
    scale = scale_ref[0]
    levels = levels_ref[0]
    codes = jnp.clip(jnp.round((x_ref[...] - x_min) / scale), 0.0, levels)
    o_ref[...] = codes * scale + x_min


def _pad_flat(x: jnp.ndarray, tile: int):
    """Flatten and edge-pad to a tile multiple (edge value keeps the
    min/max of the padded tensor identical to the original's)."""
    flat = x.reshape(-1)
    n = flat.shape[0]
    padded_n = ((n + tile - 1) // tile) * tile
    if padded_n != n:
        flat = jnp.concatenate(
            [flat, jnp.broadcast_to(flat[-1], (padded_n - n,))]
        )
    return flat, n, padded_n


@functools.partial(jax.jit, static_argnames=("tile",))
def minmax(x: jnp.ndarray, tile: int = TILE):
    """Per-tensor (min, max) via the tiled Pallas reduction (pass 1)."""
    flat, _, padded_n = _pad_flat(x, tile)
    grid = padded_n // tile
    x_min, x_max = pl.pallas_call(
        _minmax_kernel,
        grid=(grid,),
        in_specs=[pl.BlockSpec((tile,), lambda i: (i,))],
        out_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1,), flat.dtype),
            jax.ShapeDtypeStruct((1,), flat.dtype),
        ],
        interpret=True,
    )(flat)
    return x_min[0], x_max[0]


@functools.partial(jax.jit, static_argnames=("tile",))
def uaq_roundtrip(x: jnp.ndarray, levels: jnp.ndarray, tile: int = TILE):
    """Quantize-dequantize round trip of ``x`` at ``levels = 2**bits - 1``.

    Exactly what the receiving server sees after UAQ transmission; shape
    and dtype of ``x`` are preserved. Matches ``ref.uaq_roundtrip``.
    """
    levels = jnp.asarray(levels, x.dtype).reshape(-1)[:1]
    x_min, x_max = minmax(x, tile=tile)
    span = jnp.maximum(x_max - x_min, jnp.asarray(1e-8, x.dtype))
    scale = (span / levels[0]).reshape(1)
    x_min = x_min.reshape(1)

    flat, n, padded_n = _pad_flat(x, tile)
    grid = padded_n // tile
    out = pl.pallas_call(
        _roundtrip_kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((padded_n,), flat.dtype),
        interpret=True,
    )(flat, x_min, scale, levels)
    return out[:n].reshape(x.shape)
